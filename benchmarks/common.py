"""Shared benchmark harness: tiny trained LM + timing + CSV emission.

Timing goes through ``repro.serving.metrics.Timer`` (the same monotonic
clock the serving path records with) and ``best_of`` (best-of-N retry: the
min / max of N full runs, shaving OS-scheduling noise off steady-state
numbers) — the per-benchmark ad-hoc loops all route here."""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.glvq import GLVQConfig
from repro.data.calibration import collect_h, quantize_model
from repro.data.synthetic import make_batch, markov_tokens, token_batches
from repro.launch.train import make_train_step, opt_init
from repro.models import registry
from repro.optim import AdamWConfig
from repro.serving.metrics import Timer

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    with Timer() as tm:
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
    return tm.elapsed / iters * 1e6  # us


def best_of(fn: Callable, trials: int = 3, key=None, pick=min):
    """Best-of-N measurement: run ``fn()`` ``trials`` times and keep the
    best result — ``pick=min`` for latencies (default), ``pick=max`` for
    throughputs; ``key`` selects the comparison field when ``fn`` returns a
    tuple (the whole best tuple is returned)."""
    results = [fn() for _ in range(trials)]
    return pick(results, key=key) if key is not None else pick(results)


@functools.lru_cache(maxsize=1)
def tiny_trained_lm(steps: int = 80):
    """Train the benchmark model once per process (llama-family, reduced)."""
    cfg = reduced(get_config("llama2-7b"))
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=5)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False,
                                   dtype=jnp.float32))
    for batch in token_batches(cfg, 8, 32, steps, seed=0):
        params, opt, _ = step(params, opt, batch)
    return cfg, params


@functools.lru_cache(maxsize=1)
def calibration_h(n_batches: int = 2):
    cfg, params = tiny_trained_lm()
    calib = [make_batch(cfg, 4, 32, 1000 + i,
                        stream=markov_tokens(cfg.vocab, 40_000, 0))
             for i in range(n_batches)]
    return collect_h(params, calib, cfg)


def eval_ppl(params, cfg, seed: int = 99, n: int = 4) -> float:
    tot = 0.0
    for i in range(n):
        b = make_batch(cfg, 8, 32, seed + i,
                       stream=markov_tokens(cfg.vocab, 40_000, 0))
        tot += float(registry.loss_fn(params, b, cfg, dtype=jnp.float32,
                                      remat=False))
    return float(np.exp(tot / n))


def quantize_and_ppl(method: str, bits: float, *, d: int = 8,
                     iters: int = 100, use_h: bool = True,
                     qcfg_extra: Optional[dict] = None) -> float:
    cfg, params = tiny_trained_lm()
    h_acc = calibration_h() if use_h else None
    qcfg = GLVQConfig(d=d, bits=int(np.ceil(bits)), iters=iters, lr=1e-2,
                      group_size=32, **(qcfg_extra or {}))
    tm = Timer()
    q, _ = quantize_model(params, cfg, method=method, qcfg=qcfg,
                          h_acc=h_acc, bits=bits)
    return eval_ppl(q, cfg), tm.total
