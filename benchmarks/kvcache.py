"""Paged/quantized KV-cache benchmark — emits ``BENCH_kvcache.json``.

Two parts:

  * **Analytic capacity** (platform-independent; ``serving.kvcache`` byte
    accounting on the full-size llama2-7b shapes): resident cache bytes per
    stored token and max resident slots at a fixed HBM budget, per cache
    kind, at several sequence lengths.  Both caches hold the same sequences
    — "equal sequence length" — the difference is that dense reserves every
    slot's worst-case ``s_cache`` up front while the paged kinds hold only
    the blocks a sequence has touched (plus int8+f16-scale storage for the
    ``paged_q8*`` kinds).
  * **Measured throughput**: tokens/s through ``ContinuousBatcher`` on the
    reduced config per cache kind.  Off-TPU the paged kernels run via the
    XLA fallback (or Pallas interpret mode), so absolute numbers only
    compare like with like — the JSON records the platform.
  * **GLVQ codec quality**: held-out reconstruction MSE of the
    ``paged_glvq`` runtime codec with a codebook fitted by the paper's
    Babai-STE loop vs the uniform signed-int4 grid (the identity default
    book), on synthetic KV-like samples (heavy-tailed, sub-vector-aligned
    anisotropy).  Full mode asserts the calibrated book wins.

Full (non ``--smoke``) mode also asserts the acceptance bars:
``paged_glvq`` bytes/token <= 0.15x dense and calibrated MSE < uniform.

Run:  PYTHONPATH=src python -m benchmarks.kvcache [--smoke] [--out ...]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import kvcache
from repro.serving.engine import EngineConfig
from repro.serving.scheduler import ContinuousBatcher, Request

HBM_BUDGET = 16 * 1024 ** 3          # fixed cache budget for slot counts
S_CACHE_FULL = 4096                  # serving max length for the analytic part
BLOCK_SIZE_FULL = 16


def bench_capacity(arch: str = "llama2-7b"):
    """Analytic bytes/token + max resident slots on the real model shapes."""
    cfg = get_config(arch)
    rows = []
    for seq_len in (S_CACHE_FULL // 4, S_CACHE_FULL // 2, S_CACHE_FULL):
        for kind in kvcache.CACHE_KINDS:
            bpt = kvcache.bytes_per_token(cfg, kind, seq_len, S_CACHE_FULL,
                                          BLOCK_SIZE_FULL)
            slots = kvcache.max_resident_slots(cfg, kind, HBM_BUDGET,
                                               seq_len, S_CACHE_FULL,
                                               BLOCK_SIZE_FULL)
            rows.append(dict(kind="capacity", arch=arch, cache=kind,
                             seq_len=seq_len, s_cache=S_CACHE_FULL,
                             bytes_per_token=bpt, max_resident_slots=slots))
            print(f"[kvcache] {arch} s={seq_len:5d} {kind:9s}: "
                  f"{bpt / 1024:8.1f} KiB/token, {slots:6d} slots @ 16 GiB")
    return rows


def bench_throughput(smoke: bool = False):
    """Measured ContinuousBatcher tokens/s per cache kind (tiny model)."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, max_new = (4, 4) if smoke else (12, 12)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 4)))
               for _ in range(n_req)]
    rows = []
    for kind in kvcache.CACHE_KINDS:
        cb = ContinuousBatcher(params, cfg, EngineConfig(
            dtype=jnp.float32, s_cache=32, slots=4, cache_kind=kind,
            block_size=8))
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=max_new))
        cb.step()                                    # compile outside timing
        t0 = time.perf_counter()
        done = cb.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done.values())
        rows.append(dict(kind="throughput", arch="llama2-7b(reduced)",
                         cache=kind, tokens=toks, tokens_per_s=toks / dt))
        print(f"[kvcache] batcher {kind:9s}: {toks / dt:8.1f} tok/s "
              f"({toks} tokens)")
    return rows


def bench_glvq_mse(smoke: bool = False):
    """Held-out reconstruction MSE: calibrated GLVQ book vs the uniform
    signed-int4 grid, through the actual ``paged_glvq`` runtime codec
    (quantize -> word-pack -> unpack -> dequantize).  Synthetic KV-like
    samples: heavy-tailed (student-t) with a per-sub-vector anisotropy
    profile — the correlated/outlier-channel structure the learned lattice
    exploits and the uniform grid cannot."""
    from repro.core.glvq import GLVQConfig, quantize_group
    from repro.kernels import kv_cache as kvk
    rng = np.random.default_rng(0)
    hd, d, bits = 16, 4, 4
    n = 192 if smoke else 768
    prof = np.array([2.5, 1.0, 0.35, 0.12])
    mix = np.linalg.qr(rng.normal(size=(d, d)))[0] @ np.diag(prof)

    def draw(m):
        z = rng.standard_t(3, size=(m, hd // d, d))
        x = np.einsum("ij,nkj->nki", mix, z).reshape(m, hd)
        amax = np.maximum(np.abs(x).max(-1, keepdims=True), 1e-6)
        return jnp.asarray((x / amax).astype(np.float32))

    fit, held = draw(n), draw(n)
    spec = kvk.GLVQSpec(bits=bits, d=d, hd=hd)

    def codec_mse(g, mu, x):
        gi = jnp.linalg.inv(g)
        mu = jnp.asarray([mu], jnp.float32)
        w, a = kvk.glvq_quantize(x[:, None], gi[None], mu, spec)
        back = kvk.glvq_dequantize(w, a, g[None], mu, spec, jnp.float32)
        return float(jnp.mean((back[:, 0] - x) ** 2))

    ident = kvk.glvq_default_book(1, spec)
    out = quantize_group(fit.T, None, jnp.asarray(bits, jnp.int32),
                         GLVQConfig(d=d, bits=bits,
                                    iters=12 if smoke else 150))
    uniform = codec_mse(ident["kg"][0], 0.0, held)
    calibrated = codec_mse(out["g"], float(out["mu"]), held)
    print(f"[kvcache] glvq held-out MSE: uniform-int4 {uniform:.6f}  "
          f"calibrated {calibrated:.6f}  ratio {calibrated / uniform:.3f}")
    return [dict(kind="glvq_mse", codec=name, bits=bits, d=d, hd=hd,
                 held_out_mse=v)
            for name, v in (("uniform_int4", uniform),
                            ("glvq_calibrated", calibrated))]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(Path(__file__).parent
                                         / "BENCH_kvcache.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps (CI smoke)")
    args = ap.parse_args(argv)
    cap = bench_capacity()
    mid = {r["cache"]: r["bytes_per_token"] for r in cap
           if r["seq_len"] == S_CACHE_FULL // 2}
    ratio = mid["paged_q8"] / mid["dense"]
    glvq_ratio = mid["paged_glvq"] / mid["dense"]
    print(f"[kvcache] paged_q8 / dense bytes-per-token at "
          f"s={S_CACHE_FULL // 2}: {ratio:.3f}")
    print(f"[kvcache] paged_glvq / dense bytes-per-token at "
          f"s={S_CACHE_FULL // 2}: {glvq_ratio:.3f}")
    mse_rows = bench_glvq_mse(smoke=args.smoke)
    if not args.smoke:
        # acceptance bars (full mode only; smoke keeps CI fast)
        assert glvq_ratio <= 0.15, \
            f"paged_glvq bytes/token ratio {glvq_ratio:.3f} > 0.15x dense"
        mse = {r["codec"]: r["held_out_mse"] for r in mse_rows}
        assert mse["glvq_calibrated"] < mse["uniform_int4"], \
            "calibrated GLVQ book did not beat the uniform int4 grid"
    result = dict(
        platform=jax.default_backend(),
        hbm_budget_bytes=HBM_BUDGET,
        paged_q8_over_dense_bytes_per_token=ratio,
        paged_glvq_over_dense_bytes_per_token=glvq_ratio,
        rows=cap + bench_throughput(smoke=args.smoke) + mse_rows,
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[kvcache] wrote {args.out}")


if __name__ == "__main__":
    main()
