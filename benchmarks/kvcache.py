"""Paged/quantized KV-cache benchmark — emits ``BENCH_kvcache.json``.

Two parts:

  * **Analytic capacity** (platform-independent; ``serving.kvcache`` byte
    accounting on the full-size llama2-7b shapes): resident cache bytes per
    stored token and max resident slots at a fixed HBM budget, per cache
    kind, at several sequence lengths.  Both caches hold the same sequences
    — "equal sequence length" — the difference is that dense reserves every
    slot's worst-case ``s_cache`` up front while the paged kinds hold only
    the blocks a sequence has touched (plus int8+f16-scale storage for the
    ``paged_q8*`` kinds).
  * **Measured throughput**: tokens/s through ``ContinuousBatcher`` on the
    reduced config per cache kind.  Off-TPU the paged kernels run via the
    XLA fallback (or Pallas interpret mode), so absolute numbers only
    compare like with like — the JSON records the platform.

Run:  PYTHONPATH=src python -m benchmarks.kvcache [--smoke] [--out ...]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import kvcache
from repro.serving.engine import EngineConfig
from repro.serving.scheduler import ContinuousBatcher, Request

HBM_BUDGET = 16 * 1024 ** 3          # fixed cache budget for slot counts
S_CACHE_FULL = 4096                  # serving max length for the analytic part
BLOCK_SIZE_FULL = 16


def bench_capacity(arch: str = "llama2-7b"):
    """Analytic bytes/token + max resident slots on the real model shapes."""
    cfg = get_config(arch)
    rows = []
    for seq_len in (S_CACHE_FULL // 4, S_CACHE_FULL // 2, S_CACHE_FULL):
        for kind in kvcache.CACHE_KINDS:
            bpt = kvcache.bytes_per_token(cfg, kind, seq_len, S_CACHE_FULL,
                                          BLOCK_SIZE_FULL)
            slots = kvcache.max_resident_slots(cfg, kind, HBM_BUDGET,
                                               seq_len, S_CACHE_FULL,
                                               BLOCK_SIZE_FULL)
            rows.append(dict(kind="capacity", arch=arch, cache=kind,
                             seq_len=seq_len, s_cache=S_CACHE_FULL,
                             bytes_per_token=bpt, max_resident_slots=slots))
            print(f"[kvcache] {arch} s={seq_len:5d} {kind:9s}: "
                  f"{bpt / 1024:8.1f} KiB/token, {slots:6d} slots @ 16 GiB")
    return rows


def bench_throughput(smoke: bool = False):
    """Measured ContinuousBatcher tokens/s per cache kind (tiny model)."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, max_new = (4, 4) if smoke else (12, 12)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, 4)))
               for _ in range(n_req)]
    rows = []
    for kind in kvcache.CACHE_KINDS:
        cb = ContinuousBatcher(params, cfg, EngineConfig(
            dtype=jnp.float32, s_cache=32, slots=4, cache_kind=kind,
            block_size=8))
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=max_new))
        cb.step()                                    # compile outside timing
        t0 = time.perf_counter()
        done = cb.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in done.values())
        rows.append(dict(kind="throughput", arch="llama2-7b(reduced)",
                         cache=kind, tokens=toks, tokens_per_s=toks / dt))
        print(f"[kvcache] batcher {kind:9s}: {toks / dt:8.1f} tok/s "
              f"({toks} tokens)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(Path(__file__).parent
                                         / "BENCH_kvcache.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps (CI smoke)")
    args = ap.parse_args(argv)
    cap = bench_capacity()
    mid = {r["cache"]: r["bytes_per_token"] for r in cap
           if r["seq_len"] == S_CACHE_FULL // 2}
    ratio = mid["paged_q8"] / mid["dense"]
    print(f"[kvcache] paged_q8 / dense bytes-per-token at "
          f"s={S_CACHE_FULL // 2}: {ratio:.3f}")
    result = dict(
        platform=jax.default_backend(),
        hbm_budget_bytes=HBM_BUDGET,
        paged_q8_over_dense_bytes_per_token=ratio,
        rows=cap + bench_throughput(smoke=args.smoke),
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[kvcache] wrote {args.out}")


if __name__ == "__main__":
    main()
