"""Backend-comparison benchmark for the quantized-execution engine.

Times ``kernels.ops.quant_matmul`` per backend over the linear-layer shapes
of a small LM config, plus one whole-model quantized decode step, and emits
``BENCH_engine.json`` (tokens/s and analytic bytes-moved per backend) so the
perf trajectory of the engine is recorded per PR.

Run:  PYTHONPATH=src python -m benchmarks.engine [--out BENCH_engine.json]

Note on CPU numbers: ``pallas_fused`` runs in interpret mode off-TPU, so its
absolute timings are meaningless there — the JSON records the platform AND
the device count so trajectories only compare like with like.  ``bytes_moved``
is analytic (payload vs dense-materialization traffic) and
platform-independent.

Tensor-parallel rows (``--tp N``) time the shard_map path against the
replicated engine and record the physical per-device packed bytes.  Keep them
in their own JSON (``BENCH_engine_tp.json``): a forced-multi-device host
skews the single-device baseline rows, so the two trajectories must not share
a file.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.glvq import GLVQConfig
from repro.core.testing import synthetic_payload
from repro.core.quantized import QuantLinearMeta, quantize_param_tree
from repro.kernels import ops
from repro.models import registry

BACKENDS = ("xla_decode", "pallas_fused")


_payload = synthetic_payload


def _time(fn, *args, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _bytes_moved(meta: QuantLinearMeta, m: int, backend: str) -> int:
    """Analytic weight traffic per matmul: the fused path streams the packed
    payload once; the decode path additionally writes + reads dense bf16 W."""
    act = 4 * m * (meta.k + meta.n)
    payload = meta.payload_bytes()
    if backend == "pallas_fused":
        return payload + act
    dense = 2 * meta.k * meta.n
    return payload + 2 * dense + act


def bench_layers(m: int = 8, bits_list=(2, 3, 4), d: int = 8,
                 shapes=((256, 1024), (1024, 256), (256, 256))):
    """Per-layer quant_matmul across backends on LM-ish projection shapes
    (w1 / w2 / attn proj)."""
    rng = np.random.default_rng(0)
    rows = []
    for (k, n) in shapes:
        for bits in bits_list:
            meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
            payload = _payload(rng, k, n, bits, d)
            x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
            for backend in BACKENDS:
                fn = jax.jit(lambda x, p: ops.quant_matmul(
                    x, p, meta, backend=backend, out_dtype=jnp.float32))
                sec = _time(fn, x, payload)
                rows.append(dict(
                    kind="layer", k=k, n=n, bits=bits, m=m, backend=backend,
                    us_per_call=sec * 1e6,
                    tokens_per_s=m / sec,
                    bytes_moved=_bytes_moved(meta, m, backend),
                ))
                print(f"[engine] {k}x{n} b{bits} {backend:>12}: "
                      f"{sec * 1e6:9.1f} us  {m / sec:10.1f} tok/s")
    return rows


def bench_model(batch: int = 4, steps: int = 8, mesh=None):
    """Whole-model quantized decode step on the default platform backend.

    With ``mesh``, the step runs tensor-parallel (QuantTensor shard_map
    dispatch) so the sharded-vs-replicated step time lands in the JSON."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=4, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    cache = registry.cache_init(cfg, batch, 32, jnp.float32)
    backend = ops.resolve_backend()
    step = jax.jit(lambda p, c, t, pos: registry.decode_step(
        p, c, t, pos, cfg, dtype=jnp.float32, qmeta=qmeta, backend=backend,
        mesh=mesh))
    tok = jnp.zeros((batch,), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    logits, cache = step(qparams, cache, tok, pos)          # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for i in range(steps):
        pos = jnp.full((batch,), i, jnp.int32)
        logits, cache = step(qparams, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    sec = (time.perf_counter() - t0) / steps
    tp = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    row = dict(kind="model", arch="llama2-7b(reduced)", bits=4, batch=batch,
               backend=backend, tp=tp, us_per_step=sec * 1e6,
               tokens_per_s=batch / sec)
    label = f"decode_step tp={tp}" if tp > 1 else "decode_step"
    print(f"[engine] {label} {backend}: {batch / sec:.1f} tok/s")
    return [row]


def bench_tp(tp: int, m: int = 8, bits: int = 4, d: int = 8,
             k: int = 1024, n: int = 1024, smoke: bool = False):
    """Sharded-vs-replicated quantized matmul over a (dp, tp) mesh, plus the
    physical per-device packed bytes (from the addressable shards, not the
    analytic formula — so mis-sharding shows up here immediately)."""
    from repro.parallel import sharding

    ndev = jax.device_count()
    if tp < 2 or ndev < tp or ndev % tp:
        print(f"[engine] --tp {tp} skipped: {ndev} device(s); hint "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return []
    if smoke:
        k = n = 256                 # keep the CI rot-check cheap
    mesh = jax.make_mesh((ndev // tp, tp), ("data", "model"))
    rng = np.random.default_rng(0)
    rows = []
    for parallel, wname in (("column", "wq"), ("row", "wo")):
        meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
        payload = _payload(rng, k, n, bits, d)
        specs = {key: sharding._payload_leaf_spec(wname, key, v.shape, tp,
                                                  meta)
                 for key, v in payload.items()}
        sharded = jax.device_put(payload, sharding.named(specs, mesh))
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        full_bytes = int(payload["packed"].size * 4)
        per_dev = max(s.data.nbytes
                      for s in sharded["packed"].addressable_shards)
        fn_tp = jax.jit(lambda x, p: ops.quant_matmul_tp(
            x, p, meta, mesh=mesh, parallel=parallel,
            out_dtype=jnp.float32))
        fn_rep = jax.jit(lambda x, p: ops.quant_matmul(
            x, p, meta, out_dtype=jnp.float32))
        sec_tp = _time(fn_tp, x, sharded)
        sec_rep = _time(fn_rep, x, payload)
        rows.append(dict(
            kind="tp", tp=tp, parallel=parallel, k=k, n=n, bits=bits, m=m,
            backend=ops.resolve_backend(),
            us_per_call_sharded=sec_tp * 1e6,
            us_per_call_replicated=sec_rep * 1e6,
            packed_bytes_full=full_bytes,
            packed_bytes_per_device=per_dev,
            payload_shrink=per_dev / full_bytes,
        ))
        print(f"[engine] tp={tp} {parallel:>6}: sharded {sec_tp * 1e6:9.1f} "
              f"us  replicated {sec_rep * 1e6:9.1f} us  "
              f"packed/device {per_dev}/{full_bytes} "
              f"({per_dev / full_bytes:.3f}x)")
    if not smoke:                   # a second model quantize is too heavy
        rows += bench_model(batch=2, steps=2, mesh=mesh)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_engine.json, or "
                         "BENCH_engine_tp.json with --tp so multi-device "
                         "rows never overwrite the 1-device baseline "
                         "trajectory)")
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="one shape / one bit-width / few steps (CI smoke)")
    ap.add_argument("--tp", type=int, default=0,
                    help="also record tensor-parallel rows on a (dp, tp) "
                         "mesh (needs >= tp devices)")
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_engine_tp.json" if args.tp else "BENCH_engine.json"
        args.out = str(Path(__file__).parent / name)
    if args.tp:
        # TP-only rows: the single-device baseline sweep belongs to
        # BENCH_engine.json and would be skewed on a multi-device host
        rows = bench_tp(args.tp, m=args.m, smoke=args.smoke)
        if not rows:
            # don't wipe the tracked trajectory with an empty run
            raise SystemExit(f"[engine] --tp {args.tp} produced no rows; "
                             "not writing " + str(args.out))
    elif args.smoke:
        rows = bench_layers(m=args.m, bits_list=(4,), shapes=((256, 256),)) \
            + bench_model(batch=2, steps=2)
    else:
        rows = bench_layers(m=args.m) + bench_model()
    result = dict(
        platform=jax.default_backend(),
        default_backend=ops.resolve_backend(),
        devices=jax.device_count(),
        smoke=args.smoke,
        rows=rows,
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[engine] wrote {args.out}")


if __name__ == "__main__":
    main()
