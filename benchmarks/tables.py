"""One benchmark per paper table (Tables 1-4 + appendix ablations 6-13).

All accuracy numbers use the in-repo tiny trained LM (the full-scale Llama
runs of the paper need the original checkpoints + GPUs; the harness mirrors
the paper's PROTOCOL — calibration H, method grid, bit grid — at laptop
scale). Timing numbers are measured on this CPU; bytes-derived columns are
hardware-independent.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (calibration_h, emit, eval_ppl,
                               quantize_and_ppl, time_fn, tiny_trained_lm)
from repro.core.glvq import GLVQConfig
from repro.core.packing import packed_nbytes
from repro.core.quantized import QuantLinearMeta
from repro.data.calibration import quantize_model
from repro.data.synthetic import make_batch, markov_tokens


def run_table1_perplexity():
    """Table 1: perplexity by method x bit-width."""
    cfg, params = tiny_trained_lm()
    base = eval_ppl(params, cfg)
    emit("table1/fp32/16bit", 0.0, f"ppl={base:.3f}")
    grid = [("glvq", 8), ("glvq", 16), ("glvq+", 8), ("gptq", 8),
            ("rtn", 8), ("fixed-lattice", 8)]
    for bits in (2, 3, 4):
        for method, d in grid:
            tag = f"{method}-{d}D" if "glvq" in method else method
            if method != "glvq" and d != 8:
                continue
            ppl, dt = quantize_and_ppl(method, bits, d=d)
            emit(f"table1/{tag}/{bits}bit", dt * 1e6, f"ppl={ppl:.3f}")


def run_table2_downstream():
    """Table 2 proxy: zero-shot next-token top-1 accuracy (acc, not ppl)."""
    cfg, params = tiny_trained_lm()
    from repro.models import registry

    def acc(p):
        hits = tot = 0
        for i in range(4):
            b = make_batch(cfg, 8, 32, 77 + i,
                           stream=markov_tokens(cfg.vocab, 40_000, 0))
            logits = registry.forward(p, b, cfg, dtype=jnp.float32)
            pred = jnp.argmax(logits, -1)
            hits += int(jnp.sum(pred == b["labels"]))
            tot += b["labels"].size
        return hits / tot

    emit("table2/fp32", 0.0, f"acc={acc(params):.4f}")
    h_acc = calibration_h()
    for bits in (2, 3, 4):
        for method in ("glvq", "rtn", "gptq"):
            qcfg = GLVQConfig(d=8, bits=bits, iters=100, lr=1e-2, group_size=32)
            t0 = time.perf_counter()
            q, _ = quantize_model(params, cfg, method=method, qcfg=qcfg,
                                  h_acc=h_acc)
            dt = time.perf_counter() - t0
            emit(f"table2/{method}/{bits}bit", dt * 1e6, f"acc={acc(q):.4f}")


def run_table3_fractional():
    """Table 3: fractional and sub-2-bit rates via SDBA mixes."""
    for bits in (1.0, 1.5, 2.0):
        ppl, dt = quantize_and_ppl("glvq", bits)
        emit(f"table3/glvq/{bits}bit", dt * 1e6, f"ppl={ppl:.3f}")
    ppl, dt = quantize_and_ppl("rtn", 2.0)
    emit("table3/rtn/2.0bit", dt * 1e6, f"ppl={ppl:.3f}")


def run_table4_throughput():
    """Table 4: decode throughput + memory traffic (XLA paths on CPU;
    packed-vs-dense bytes are the hardware-independent quantity)."""
    from repro.core import packing
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    k = n = 1024
    m = 8
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32).astype(jnp.bfloat16)
    dense = jax.jit(lambda x, w: x @ w.astype(x.dtype))
    us = time_fn(dense, x, w)
    emit("table4/dense-bf16-matvec", us, f"weight_bytes={k * n * 2}")

    for bits, d in [(2, 8), (2, 32), (4, 8)]:
        n_g = k // 128
        codes = rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), size=(k, n))
        packed = packing.pack_codes(jnp.asarray(codes, jnp.int32), bits)
        g = jnp.asarray(rng.normal(size=(n_g, d, d)) * 0.05 + np.eye(d) * 0.2,
                        jnp.float32)
        mu = jnp.full((n_g,), 60.0, jnp.float32)
        scale = jnp.ones((n_g,), jnp.float32)
        fn = jax.jit(lambda x, p, g, mu, s: ref.glvq_matmul_ref(
            x, p, g, mu, s, bits=bits, d=d, n=n))
        us = time_fn(fn, x, packed, g, mu, scale)
        meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
        emit(f"table4/glvq-{d}D-{bits}bit-xla", us,
             f"weight_bytes={meta.payload_bytes()};"
             f"bw_reduction={k * n * 2 / meta.payload_bytes():.2f}x")


def run_ablation_bit_allocation():
    """Table 6: SDBA vs uniform bits."""
    for bits in (2, 3):
        p1, _ = quantize_and_ppl("glvq", bits)
        p2, _ = quantize_and_ppl("glvq-u", bits)
        emit(f"table6/sdba-vs-uniform/{bits}bit", 0.0,
             f"ppl_sdba={p1:.3f};ppl_uniform={p2:.3f}")


def run_ablation_lattice():
    """Table 7: adaptive vs fixed lattice."""
    for bits in (2, 3):
        p1, _ = quantize_and_ppl("glvq", bits)
        p2, _ = quantize_and_ppl("fixed-lattice", bits)
        emit(f"table7/adaptive-vs-fixed/{bits}bit", 0.0,
             f"ppl_learned={p1:.3f};ppl_fixed={p2:.3f}")


def run_ablation_companding():
    """Table 8: group-specific companding on/off."""
    for bits in (2, 3):
        p1, _ = quantize_and_ppl("glvq", bits)
        p2, _ = quantize_and_ppl("glvq", bits,
                                 qcfg_extra=dict(use_companding=False))
        emit(f"table8/companding/{bits}bit", 0.0,
             f"ppl_on={p1:.3f};ppl_off={p2:.3f}")


def run_ablation_group_size():
    """Tables 9/10: group-size sweep (storage overhead derived per App. B)."""
    for gs in (16, 32, 64):
        cfg, params = tiny_trained_lm()
        qcfg = GLVQConfig(d=8, bits=3, iters=60, lr=1e-2, group_size=gs)
        q, _ = quantize_model(params, cfg, method="glvq", qcfg=qcfg,
                              h_acc=calibration_h())
        # App. B overhead: (16 d^2 + 16) / (gs * n * b) per group
        oh = (16 * 8 * 8 + 16) / (gs * 64 * 3)
        emit(f"table9/group{gs}", 0.0,
             f"ppl={eval_ppl(q, cfg):.3f};side_info_overhead={oh * 100:.2f}%")


def run_ablation_calibration_size():
    """Table 11: calibration-set size."""
    cfg, params = tiny_trained_lm()
    from repro.data.calibration import collect_h
    for nb in (1, 2, 4):
        calib = [make_batch(cfg, 4, 32, 1000 + i,
                            stream=markov_tokens(cfg.vocab, 40_000, 0))
                 for i in range(nb)]
        h_acc = collect_h(params, calib, cfg)
        qcfg = GLVQConfig(d=8, bits=2, iters=60, lr=1e-2, group_size=32)
        q, _ = quantize_model(params, cfg, method="glvq", qcfg=qcfg,
                              h_acc=h_acc)
        emit(f"table11/calib{nb * 128}tok", 0.0,
             f"ppl={eval_ppl(q, cfg):.3f}")


def run_ablation_rounding():
    """Tables 12/13: Babai vs greedy coordinate descent."""
    for bits in (2, 4):
        p1, t1 = quantize_and_ppl("glvq", bits)
        p2, t2 = quantize_and_ppl("gcd", bits)
        emit(f"table12/babai-vs-gcd/{bits}bit", t1 * 1e6,
             f"ppl_babai={p1:.3f};ppl_gcd={p2:.3f};gcd_us={t2 * 1e6:.0f}")


def run_table5_overhead():
    """Table 5 (App. B): side-info overhead per Eq. 27 — exact reproduction.

    OH = (16 d^2 + 16) / (m_g * n_g * b); paper reports e.g. 0.10% for
    (d=8, m=4096, n=128, b=2) and 1.56% for (d=32, n=128, b=2).
    """
    m_g = 4096
    for d in (8, 16, 32):
        for n_g in (128, 256):
            ohs = ["%.2f" % (100 * (16 * d * d + 16) / (m_g * n_g * b))
                   for b in (2, 3, 4)]
            emit(f"table5/d{d}/n{n_g}", 0.0,
                 f"overhead_pct_b2/3/4={'/'.join(ohs)}")
