"""Fused paged-attention benchmark — emits ``BENCH_attention.json``.

Two parts:

  * **Analytic HBM traffic** (platform-independent, full llama2-7b shapes):
    modeled bytes MOVED per decode token per layer to attend a depth-``s``
    paged history.  The unfused path (``kv_cache.gather`` then SDPA) reads
    the stored codes, WRITES the dense dequantized ``[S, KV, hd]`` slab,
    and reads it back in attention — three passes over the history, two of
    them at compute precision.  The fused kernel streams the codes through
    VMEM exactly once; neither the slab nor the dequantized cache exists in
    HBM.  The acceptance bar: fused / unfused <= 0.5 at s=2048 for
    ``paged_q8`` (it lands ~0.2: int8 codes once vs codes + 2x bf16 slab).
  * **Measured latency**: ms/token through ``attention.paged_attention``,
    fused vs unfused backend, at decode (T=1) and chunk widths.  Off-TPU
    the fused kernel runs in Pallas interpret mode, so absolute fused
    numbers are NOT indicative there — the JSON records the platform and
    the unfused timings remain a real XLA baseline.

Run:  PYTHONPATH=src python -m benchmarks.attention [--smoke] [--out ...]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels import attention as attn
from repro.kernels import kv_cache as kvk

PAGED_KINDS = ("paged", "paged_q8", "paged_q8c")
COMPUTE_DTYPE = jnp.bfloat16          # serving compute/store precision
SCALE_BYTES = 2                        # ksc/vsc are f16 per token per head


def _per_token_key_bytes(kind: str, hd: int) -> int:
    """Stored bytes for one (token, kv-head) K+V pair."""
    if kind == "paged":
        return 2 * hd * COMPUTE_DTYPE.dtype.itemsize
    return 2 * (hd + SCALE_BYTES)                  # int8 codes + f16 scale


def bench_bytes_model(arch: str = "llama2-7b"):
    """Modeled HBM bytes moved per decode token per layer, fused vs
    unfused, on the real model shapes."""
    cfg = get_config(arch)
    kv, hd = cfg.n_kv_heads, cfg.hd
    rows = []
    for s in (512, 2048, 4096):
        for kind in PAGED_KINDS:
            codes = s * kv * _per_token_key_bytes(kind, hd)
            slab = 2 * s * kv * hd * COMPUTE_DTYPE.dtype.itemsize
            unfused = codes + 2 * slab             # read codes, write+read slab
            fused = codes                          # one pass, as codes
            rows.append(dict(kind="bytes_model", arch=arch, cache=kind,
                             seq_len=s, unfused_bytes_per_token=unfused,
                             fused_bytes_per_token=fused,
                             ratio=fused / unfused))
            print(f"[attention] {arch} s={s:5d} {kind:9s}: "
                  f"{unfused / 1024:9.1f} KiB unfused -> "
                  f"{fused / 1024:8.1f} KiB fused "
                  f"({fused / unfused:.3f}x) per token per layer")
    return rows


def _rand_pools(rng, mode, nblk, bs, kv, hd):
    pools = kvk.pool_init(nblk, bs, kv, hd, jnp.float32, mode)
    out = {}
    for n, a in pools.items():
        x = rng.normal(size=a.shape)
        out[n] = jnp.asarray((x * 40).clip(-127, 127), a.dtype) \
            if a.dtype == jnp.int8 else jnp.asarray(np.abs(x), a.dtype)
    return out


def bench_measured(smoke: bool = False):
    """Measured ms/token, fused vs unfused, decode + chunk widths."""
    rng = np.random.default_rng(0)
    b, bs, nb, kv, h, hd = (2, 8, 4, 2, 4, 32) if smoke \
        else (4, 16, 8, 4, 8, 64)
    iters = 3 if smoke else 10
    table = jnp.asarray(
        rng.permutation(np.arange(1, 1 + b * nb)).reshape(b, nb), jnp.int32)
    pos = jnp.asarray([bs * nb - 2] * b, jnp.int32)
    rows = []
    for kind in PAGED_KINDS:
        pools = _rand_pools(rng, kind, 1 + b * nb, bs, kv, hd)
        for t in (1, 4):
            q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
            lens = pos + t
            for be in ("xla", "pallas"):
                fn = jax.jit(lambda q, pl_: attn.paged_attention(
                    q, pl_, table, pos - t + 1, lens, mode=kind,
                    backend=be, out_dtype=jnp.float32))
                fn(q, pools).block_until_ready()   # compile outside timing
                t0 = time.perf_counter()
                for _ in range(iters):
                    fn(q, pools).block_until_ready()
                ms = (time.perf_counter() - t0) / iters / (b * t) * 1e3
                rows.append(dict(kind="measured", cache=kind, width=t,
                                 backend=be, ms_per_token=ms))
                print(f"[attention] {kind:9s} T={t} {be:6s}: "
                      f"{ms:9.3f} ms/token")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(Path(__file__).parent
                                         / "BENCH_attention.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI smoke)")
    args = ap.parse_args(argv)
    rows = bench_bytes_model()
    at2048 = {r["cache"]: r["ratio"] for r in rows if r["seq_len"] == 2048}
    print(f"[attention] fused / unfused modeled bytes at s=2048: "
          + ", ".join(f"{k}={v:.3f}" for k, v in at2048.items()))
    assert at2048["paged_q8"] <= 0.5, \
        "fused paged_q8 must halve modeled HBM traffic"
    result = dict(
        platform=jax.default_backend(),
        compute_dtype=str(COMPUTE_DTYPE.dtype),
        fused_over_unfused_bytes_s2048=at2048,
        rows=rows + bench_measured(smoke=args.smoke),
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[attention] wrote {args.out}")


if __name__ == "__main__":
    main()
