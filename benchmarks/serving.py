"""Serving benchmark — emits ``BENCH_serving.json``.

Main parts:

  * **TTFT (time-to-first-token)**: one request with a long prompt through
    the serving engine at several ``chunk_size`` settings.  ``chunk=1`` is
    the token-by-token baseline (one engine iteration per prompt token);
    chunked prefill consumes up to ``chunk_size`` prompt tokens per
    iteration, so TTFT drops roughly linearly until per-iteration overhead
    stops dominating.  Compilation is excluded (a warm-up request with the
    same program shapes runs first).
  * **Hybrid throughput**: a batch of requests (prefill + decode slots mixed
    in the same engine iterations, Sarathi-style) — steady-state tokens/s
    per chunk size.
  * **Scheduler policies at equal token budget**: ``FCFSPolicy`` with a
    fixed chunk such that a worst-case iteration packs ``budget`` tokens
    (slots x chunk = budget) vs ``TokenBudgetPolicy(budget)`` whose widths
    adapt along a ladder — a lone prefill gets the whole budget as one wide
    slab (fewer iterations to first token), a prefill sharing the engine
    with decode slots is throttled to the same cap.  Rows record TTFT and
    hybrid tokens/s for both at the same per-iteration budget.
  * **Prefix cache**: N users x one shared system prompt — cold vs
    cache-hit TTFT at equal budget and workload tokens/s cache on vs off
    (full mode asserts the >= 3x hit-TTFT bar).

Off-TPU the kernels run via the XLA fallback (or Pallas interpret mode), so
absolute numbers only compare like with like — the JSON records the
platform.

Run:  PYTHONPATH=src python -m benchmarks.serving [--smoke] [--out ...]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Timer, best_of
from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import (ContinuousBatcher, EngineConfig, Request,
                           TokenBudgetPolicy)

PROMPT_LEN_FULL = 512
CHUNKS_FULL = (1, 16, 64, 128)
PROMPT_LEN_SMOKE = 32
CHUNKS_SMOKE = (1, 8)


def _batcher(params, cfg, s_cache, chunk, policy=None, slots=2):
    ecfg = EngineConfig(dtype=jnp.float32, s_cache=s_cache, slots=slots,
                        chunk_size=chunk)
    return ContinuousBatcher(params, cfg, ecfg, policy=policy)


def _ttft(cb, prompt, warm_prompt=None):
    """Seconds from submit to the first generated token (compile excluded).
    The warm-up request replays the same program shapes first."""
    cb.submit(Request(rid=-1, prompt=list(warm_prompt or prompt), max_new=2))
    cb.run()
    cb.finished.clear()
    req = Request(rid=0, prompt=list(prompt), max_new=4)
    cb.submit(req)
    tm = Timer()
    steps = 0
    while not req.tokens and steps < 100_000:
        cb.step()
        steps += 1
    ttft = tm.total
    cb.run()
    assert req.done and len(req.tokens) == 4
    return ttft, steps


def bench_ttft(smoke: bool = False):
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompt_len = PROMPT_LEN_SMOKE if smoke else PROMPT_LEN_FULL
    chunks = CHUNKS_SMOKE if smoke else CHUNKS_FULL
    s_cache = prompt_len + 16
    rng = np.random.default_rng(0)
    prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
    rows, tokens = [], {}
    for chunk in chunks:
        cb = _batcher(params, cfg, s_cache, chunk)
        ttft, steps = _ttft(cb, prompt,
                            warm_prompt=prompt[: max(2, chunk + 1)])
        rows.append(dict(kind="ttft", arch="llama2-7b(reduced)",
                         prompt_len=prompt_len, chunk_size=chunk,
                         ttft_s=ttft, prefill_steps=steps))
        tokens[chunk] = ttft
        print(f"[serving] TTFT prompt={prompt_len} chunk={chunk:4d}: "
              f"{ttft * 1e3:8.1f} ms ({steps} engine iterations)")
    base = tokens[1]
    for r in rows:
        r["speedup_vs_token_by_token"] = base / r["ttft_s"]
    return rows


def _hybrid_tokens_per_s(cb, prompts, max_new):
    """Warm every program shape with the same workload, then time it.

    Inter-token latency percentiles come from the batcher's own
    ``serving_inter_token_seconds`` histogram — the telemetry registry is
    re-initialized after the warm run so the stats cover the timed run only
    (no compile-time gaps in the tail)."""
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=-1 - i, prompt=list(p), max_new=max_new))
    cb.run()
    cb.finished.clear()
    cb._init_telemetry(None, None)          # fresh registry: timed run only
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=list(p), max_new=max_new))
    tm = Timer()
    done = cb.run()
    dt = tm.total
    toks = sum(len(r.tokens) for r in done.values())
    proc = toks + sum(len(p) for p in prompts)      # incl. prompt tokens
    itl = cb.metrics.histogram("serving_inter_token_seconds")
    return proc / dt, toks, proc, itl


def bench_hybrid_throughput(smoke: bool = False):
    """Mixed prefill+decode batches: total tokens/s through request churn."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    n_req, p_len, max_new = (4, 12, 4) if smoke else (12, 48, 16)
    chunks = CHUNKS_SMOKE if smoke else (1, 16, 64)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    rows = []
    for chunk in chunks:
        cb = _batcher(params, cfg, p_len + max_new + 8, chunk)
        tps, toks, proc, itl = _hybrid_tokens_per_s(cb, prompts, max_new)
        p50, p95 = itl.percentile(50), itl.percentile(95)
        rows.append(dict(kind="hybrid", arch="llama2-7b(reduced)",
                         requests=n_req, prompt_len=p_len, chunk_size=chunk,
                         generated=toks, tokens_per_s=tps,
                         itl_p50_ms=p50 * 1e3 if p50 is not None else None,
                         itl_p95_ms=p95 * 1e3 if p95 is not None else None))
        print(f"[serving] hybrid chunk={chunk:4d}: {tps:8.1f} tok/s "
              f"({toks} generated, {proc} processed; ITL p50 "
              f"{(p50 or 0) * 1e3:.2f} ms p95 {(p95 or 0) * 1e3:.2f} ms)")
    return rows


def bench_policies(smoke: bool = False):
    """FCFS vs TokenBudgetPolicy at the SAME worst-case per-iteration token
    budget (slots x fcfs_chunk == budget == TokenBudgetPolicy cap)."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    slots = 2
    budget = 16 if smoke else 64
    prompt_len = 24 if smoke else 256
    n_req, p_len, max_new = (4, 12, 4) if smoke else (12, 48, 16)
    rng = np.random.default_rng(2)
    long_prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    s_cache = prompt_len + 16

    setups = [
        ("fcfs", budget // slots, None),
        ("token_budget", budget, TokenBudgetPolicy(budget)),
    ]
    trials = 1 if smoke else 3            # best-of-N: steady-state numbers,
    rows = []                             # not OS-scheduling noise
    for name, chunk, policy in setups:
        cb = _batcher(params, cfg, s_cache, chunk, policy=policy,
                      slots=slots)

        def _ttft_once():
            cb.finished.clear()
            return _ttft(cb, long_prompt, warm_prompt=long_prompt)

        ttft, steps = best_of(_ttft_once, trials, key=lambda r: r[0])
        cb2 = _batcher(params, cfg, p_len + max_new + 8, chunk,
                       policy=policy, slots=slots)

        def _tps_once():
            cb2.finished.clear()
            return _hybrid_tokens_per_s(cb2, prompts, max_new)

        tps, toks, _, _ = best_of(_tps_once, trials, key=lambda r: r[0],
                                  pick=max)
        rows.append(dict(kind="policy", arch="llama2-7b(reduced)",
                         policy=name, token_budget=budget, chunk_size=chunk,
                         slots=slots, prompt_len=prompt_len, ttft_s=ttft,
                         prefill_steps=steps, requests=n_req,
                         hybrid_prompt_len=p_len, tokens_per_s=tps))
        print(f"[serving] policy={name:12s} budget={budget}: TTFT "
              f"{ttft * 1e3:8.1f} ms ({steps} iters), hybrid {tps:8.1f} "
              f"tok/s")
    fcfs, tb = rows
    tb["ttft_speedup_vs_fcfs"] = fcfs["ttft_s"] / tb["ttft_s"]
    tb["throughput_vs_fcfs"] = tb["tokens_per_s"] / fcfs["tokens_per_s"]
    print(f"[serving] token_budget vs fcfs at budget={budget}: "
          f"TTFT {tb['ttft_speedup_vs_fcfs']:.2f}x, tokens/s "
          f"{tb['throughput_vs_fcfs']:.2f}x")
    return rows


def bench_metrics_overhead(smoke: bool = False):
    """Telemetry cost gate: the same hybrid workload with metrics on vs off
    (``EngineConfig.metrics``), best-of-N tokens/s each.  Asserts the
    recording path costs < 2% throughput — the telemetry is host-side
    floats on pre-bound handles, so a regression here means someone put
    work on the hot path."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    n_req, p_len, max_new, chunk = (4, 12, 8, 8) if smoke \
        else (8, 32, 16, 16)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    trials = 3 if smoke else 5
    cbs = {}
    for label, on in (("on", True), ("off", False)):
        ecfg = EngineConfig(dtype=jnp.float32, s_cache=p_len + max_new + 8,
                            slots=2, chunk_size=chunk, metrics=on)
        cbs[label] = ContinuousBatcher(params, cfg, ecfg)

    def _round():
        out = {}
        for label, cb in cbs.items():
            def _once():
                cb.finished.clear()
                return _hybrid_tokens_per_s(cb, prompts, max_new)[0]
            out[label] = best_of(_once, trials, pick=max)
        return out

    # OS-scheduling noise on shared CPU dwarfs the 2% budget in any single
    # measurement, so the gate retries: a REAL recording-cost regression
    # fails every round, a noisy spike passes on a clean one.  Smoke runs
    # (CI) share the machine with the rest of the pipeline, where even
    # five rounds can all land dirty — there the gate is advisory and
    # only the full bench run enforces it.
    rounds = []
    for _ in range(5):
        tps = _round()
        rounds.append(tps)
        if tps["on"] >= 0.98 * tps["off"]:
            break
    overhead_pct = (1.0 - tps["on"] / tps["off"]) * 100.0
    print(f"[serving] metrics overhead: on {tps['on']:.1f} tok/s, "
          f"off {tps['off']:.1f} tok/s ({overhead_pct:+.2f}%, "
          f"{len(rounds)} round(s))")
    detail = "; ".join(f"on={r['on']:.1f} off={r['off']:.1f}" for r in rounds)
    if smoke:
        if tps["on"] < 0.98 * tps["off"]:
            print(f"[serving] WARNING: metrics overhead >2% in every smoke "
                  f"round ({detail}) — advisory only under CI load")
    else:
        assert tps["on"] >= 0.98 * tps["off"], (
            f"metrics recording costs >2% tokens/s in every round: {detail}")
    return [dict(kind="metrics_overhead", arch="llama2-7b(reduced)",
                 requests=n_req, prompt_len=p_len, chunk_size=chunk,
                 tokens_per_s_metrics_on=tps["on"],
                 tokens_per_s_metrics_off=tps["off"],
                 overhead_pct=overhead_pct)]


def bench_debug_overhead(smoke: bool = False):
    """Sanitizer cost gate for ``EngineConfig.debug_checks``.

    The hard assertion is STRUCTURAL, not a timing race: with
    debug_checks=False the scheduler jits the raw step closure, so its
    jaxpr must contain zero checkify primitives — the disabled sanitizer
    is graph-free and tokens/s is unchanged by construction.  The enabled
    engine must show the checks in-graph (the feature is live), and its
    measured overhead is recorded for the perf trajectory."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    n_req, p_len, max_new, chunk = (4, 12, 8, 8) if smoke \
        else (8, 32, 16, 16)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    trials = 3 if smoke else 5
    tps, cbs = {}, {}
    for label, on in (("on", True), ("off", False)):
        ecfg = EngineConfig(dtype=jnp.float32, s_cache=p_len + max_new + 8,
                            slots=2, chunk_size=chunk, cache_kind="paged",
                            block_size=4, debug_checks=on)
        cb = cbs[label] = ContinuousBatcher(params, cfg, ecfg)

        def _once():
            cb.finished.clear()
            return _hybrid_tokens_per_s(cb, prompts, max_new)[0]

        tps[label] = best_of(_once, trials, pick=max)

    def _step_prims(cb):
        b = len(cb.slots)
        vi = jnp.zeros((b,), jnp.int32)
        vf = jnp.zeros((b,), jnp.float32)
        jaxpr = jax.make_jaxpr(cb._step_fn)(
            cb.params, cb.cache, jnp.zeros((b, 1), jnp.int32),
            vi, vi, vi, vi, vf, vi, jnp.ones((b,), jnp.float32))
        return {e.primitive.name for e in jaxpr.jaxpr.eqns}

    off_prims = _step_prims(cbs["off"])
    assert not any("check" in p for p in off_prims), (
        f"debug_checks=off traced checkify primitives into the step "
        f"(graph must be unchanged): {sorted(off_prims)}")
    assert cbs["off"]._debug is False \
        and not hasattr(cbs["off"], "_checked_step")
    assert cbs["on"]._debug is True and hasattr(cbs["on"], "_checked_step")
    overhead_pct = (1.0 - tps["on"] / tps["off"]) * 100.0
    print(f"[serving] debug_checks overhead: on {tps['on']:.1f} tok/s, "
          f"off {tps['off']:.1f} tok/s ({overhead_pct:+.2f}%); "
          "off-graph checkify-free")
    return [dict(kind="debug_overhead", arch="llama2-7b(reduced)",
                 requests=n_req, prompt_len=p_len, chunk_size=chunk,
                 cache_kind="paged",
                 tokens_per_s_debug_on=tps["on"],
                 tokens_per_s_debug_off=tps["off"],
                 overhead_pct=overhead_pct,
                 off_graph_checkify_free=True)]


def bench_prefix_cache(smoke: bool = False):
    """N users x one shared system prompt: the prefix-cache workload.

    Every request is ``shared_prefix + per-user tail``.  With the cache on,
    the first request cold-prefills and registers the prefix blocks; every
    later request aliases them (refcounted, CoW at the divergence block) and
    prefills only its tail, so its TTFT collapses to roughly one engine
    iteration.  Rows record cold vs hit TTFT at the SAME chunk budget plus
    workload tokens/s with the cache on vs off (the off number doubles as
    the no-regression reference for the disabled path).  Full mode asserts
    the >= 3x hit-TTFT acceptance bar; smoke just records (1-iteration
    timings are OS-noise territory)."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    n_users, shared_len, tail_len, max_new, chunk, block = \
        (4, 24, 4, 4, 8, 4) if smoke else (16, 192, 16, 8, 32, 16)
    p_len = shared_len + tail_len
    s_cache = p_len + max_new + 8
    rng = np.random.default_rng(5)
    shared = list(map(int, rng.integers(1, cfg.vocab, shared_len)))
    prompts = [shared + list(map(int, rng.integers(1, cfg.vocab, tail_len)))
               for _ in range(n_users)]
    # same token count, disjoint ids: warms every program shape without
    # seeding the radix with the measured prefix
    warm = list(map(int, rng.integers(1, cfg.vocab, p_len)))

    def _cb(prefix_on):
        ecfg = EngineConfig(dtype=jnp.float32, s_cache=s_cache, slots=2,
                            chunk_size=chunk, cache_kind="paged_q8",
                            block_size=block, prefix_cache=prefix_on)
        return ContinuousBatcher(params, cfg, ecfg)

    def _ttft_one(cb, prompt, rid):
        req = Request(rid=rid, prompt=list(prompt), max_new=max_new)
        cb.submit(req)
        tm = Timer()
        steps = 0
        while not req.tokens and steps < 100_000:
            cb.step()
            steps += 1
        ttft = tm.total
        cb.run()
        return ttft, steps

    cb = _cb(True)
    _ttft_one(cb, warm, rid=-1)                   # compile, radix-disjoint
    cold, cold_steps = _ttft_one(cb, prompts[0], rid=0)   # registers prefix
    hit, hit_steps = _ttft_one(cb, prompts[1], rid=1)     # aliases it
    assert cb.prefix.hits >= 1, "hit request missed the prefix cache"
    speedup = cold / hit
    print(f"[serving] prefix TTFT shared={shared_len}: cold "
          f"{cold * 1e3:8.1f} ms ({cold_steps} iters) vs hit "
          f"{hit * 1e3:8.1f} ms ({hit_steps} iters) = {speedup:.1f}x")
    if not smoke:
        assert speedup >= 3.0, (
            f"cache-hit TTFT must be >= 3x cold prefill at equal budget, "
            f"got {speedup:.2f}x (cold {cold * 1e3:.1f} ms / hit "
            f"{hit * 1e3:.1f} ms)")

    tps = {}
    for label, on in (("on", True), ("off", False)):
        cb = _cb(on)
        tps[label], toks, proc, _ = _hybrid_tokens_per_s(cb, prompts,
                                                         max_new)
        extra = ""
        if on:
            st = cb.prefix
            extra = (f" (hits {st.hits}, reused {st.tokens_reused} tok, "
                     f"CoW {st.cow_copies}, evictions {st.evictions})")
        print(f"[serving] prefix workload cache={label:3s}: "
              f"{tps[label]:8.1f} tok/s{extra}")
    return [dict(kind="prefix_cache", arch="llama2-7b(reduced)",
                 users=n_users, shared_prefix=shared_len, tail_len=tail_len,
                 chunk_size=chunk, block_size=block, cache_kind="paged_q8",
                 ttft_cold_s=cold, ttft_hit_s=hit,
                 ttft_hit_speedup=speedup,
                 prefill_steps_cold=cold_steps, prefill_steps_hit=hit_steps,
                 tokens_per_s_cache_on=tps["on"],
                 tokens_per_s_cache_off=tps["off"],
                 throughput_on_vs_off=tps["on"] / tps["off"])]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(Path(__file__).parent
                                         / "BENCH_serving.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps (CI smoke)")
    args = ap.parse_args(argv)
    ttft = bench_ttft(smoke=args.smoke)
    best = max(r["speedup_vs_token_by_token"] for r in ttft)
    print(f"[serving] best TTFT speedup over token-by-token: {best:.1f}x")
    result = dict(
        platform=jax.default_backend(),
        prompt_len=ttft[0]["prompt_len"],
        best_ttft_speedup=best,
        rows=ttft + bench_hybrid_throughput(smoke=args.smoke)
        + bench_policies(smoke=args.smoke)
        + bench_metrics_overhead(smoke=args.smoke)
        + bench_debug_overhead(smoke=args.smoke)
        + bench_prefix_cache(smoke=args.smoke),
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[serving] wrote {args.out}")


if __name__ == "__main__":
    main()
