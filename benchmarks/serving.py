"""Serving benchmark — emits ``BENCH_serving.json``.

Three parts:

  * **TTFT (time-to-first-token)**: one request with a long prompt through
    the serving engine at several ``chunk_size`` settings.  ``chunk=1`` is
    the token-by-token baseline (one engine iteration per prompt token);
    chunked prefill consumes up to ``chunk_size`` prompt tokens per
    iteration, so TTFT drops roughly linearly until per-iteration overhead
    stops dominating.  Compilation is excluded (a warm-up request with the
    same program shapes runs first).
  * **Hybrid throughput**: a batch of requests (prefill + decode slots mixed
    in the same engine iterations, Sarathi-style) — steady-state tokens/s
    per chunk size.
  * **Scheduler policies at equal token budget**: ``FCFSPolicy`` with a
    fixed chunk such that a worst-case iteration packs ``budget`` tokens
    (slots x chunk = budget) vs ``TokenBudgetPolicy(budget)`` whose widths
    adapt along a ladder — a lone prefill gets the whole budget as one wide
    slab (fewer iterations to first token), a prefill sharing the engine
    with decode slots is throttled to the same cap.  Rows record TTFT and
    hybrid tokens/s for both at the same per-iteration budget.

Off-TPU the kernels run via the XLA fallback (or Pallas interpret mode), so
absolute numbers only compare like with like — the JSON records the
platform.

Run:  PYTHONPATH=src python -m benchmarks.serving [--smoke] [--out ...]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import (ContinuousBatcher, EngineConfig, Request,
                           TokenBudgetPolicy)

PROMPT_LEN_FULL = 512
CHUNKS_FULL = (1, 16, 64, 128)
PROMPT_LEN_SMOKE = 32
CHUNKS_SMOKE = (1, 8)


def _batcher(params, cfg, s_cache, chunk, policy=None, slots=2):
    ecfg = EngineConfig(dtype=jnp.float32, s_cache=s_cache, slots=slots,
                        chunk_size=chunk)
    return ContinuousBatcher(params, cfg, ecfg, policy=policy)


def _ttft(cb, prompt, warm_prompt=None):
    """Seconds from submit to the first generated token (compile excluded).
    The warm-up request replays the same program shapes first."""
    cb.submit(Request(rid=-1, prompt=list(warm_prompt or prompt), max_new=2))
    cb.run()
    cb.finished.clear()
    req = Request(rid=0, prompt=list(prompt), max_new=4)
    cb.submit(req)
    t0 = time.perf_counter()
    steps = 0
    while not req.tokens and steps < 100_000:
        cb.step()
        steps += 1
    ttft = time.perf_counter() - t0
    cb.run()
    assert req.done and len(req.tokens) == 4
    return ttft, steps


def bench_ttft(smoke: bool = False):
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompt_len = PROMPT_LEN_SMOKE if smoke else PROMPT_LEN_FULL
    chunks = CHUNKS_SMOKE if smoke else CHUNKS_FULL
    s_cache = prompt_len + 16
    rng = np.random.default_rng(0)
    prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
    rows, tokens = [], {}
    for chunk in chunks:
        cb = _batcher(params, cfg, s_cache, chunk)
        ttft, steps = _ttft(cb, prompt,
                            warm_prompt=prompt[: max(2, chunk + 1)])
        rows.append(dict(kind="ttft", arch="llama2-7b(reduced)",
                         prompt_len=prompt_len, chunk_size=chunk,
                         ttft_s=ttft, prefill_steps=steps))
        tokens[chunk] = ttft
        print(f"[serving] TTFT prompt={prompt_len} chunk={chunk:4d}: "
              f"{ttft * 1e3:8.1f} ms ({steps} engine iterations)")
    base = tokens[1]
    for r in rows:
        r["speedup_vs_token_by_token"] = base / r["ttft_s"]
    return rows


def _hybrid_tokens_per_s(cb, prompts, max_new):
    """Warm every program shape with the same workload, then time it."""
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=-1 - i, prompt=list(p), max_new=max_new))
    cb.run()
    cb.finished.clear()
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=list(p), max_new=max_new))
    t0 = time.perf_counter()
    done = cb.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done.values())
    proc = toks + sum(len(p) for p in prompts)      # incl. prompt tokens
    return proc / dt, toks, proc


def bench_hybrid_throughput(smoke: bool = False):
    """Mixed prefill+decode batches: total tokens/s through request churn."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    n_req, p_len, max_new = (4, 12, 4) if smoke else (12, 48, 16)
    chunks = CHUNKS_SMOKE if smoke else (1, 16, 64)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    rows = []
    for chunk in chunks:
        cb = _batcher(params, cfg, p_len + max_new + 8, chunk)
        tps, toks, proc = _hybrid_tokens_per_s(cb, prompts, max_new)
        rows.append(dict(kind="hybrid", arch="llama2-7b(reduced)",
                         requests=n_req, prompt_len=p_len, chunk_size=chunk,
                         generated=toks, tokens_per_s=tps))
        print(f"[serving] hybrid chunk={chunk:4d}: {tps:8.1f} tok/s "
              f"({toks} generated, {proc} processed)")
    return rows


def bench_policies(smoke: bool = False):
    """FCFS vs TokenBudgetPolicy at the SAME worst-case per-iteration token
    budget (slots x fcfs_chunk == budget == TokenBudgetPolicy cap)."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    slots = 2
    budget = 16 if smoke else 64
    prompt_len = 24 if smoke else 256
    n_req, p_len, max_new = (4, 12, 4) if smoke else (12, 48, 16)
    rng = np.random.default_rng(2)
    long_prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
    prompts = [list(map(int, rng.integers(1, cfg.vocab, p_len)))
               for _ in range(n_req)]
    s_cache = prompt_len + 16

    setups = [
        ("fcfs", budget // slots, None),
        ("token_budget", budget, TokenBudgetPolicy(budget)),
    ]
    trials = 1 if smoke else 3            # best-of-N: steady-state numbers,
    rows = []                             # not OS-scheduling noise
    for name, chunk, policy in setups:
        cb = _batcher(params, cfg, s_cache, chunk, policy=policy,
                      slots=slots)
        ttft, steps = _ttft(cb, long_prompt, warm_prompt=long_prompt)
        for _ in range(trials - 1):
            cb.finished.clear()
            t2, _ = _ttft(cb, long_prompt, warm_prompt=long_prompt)
            ttft = min(ttft, t2)
        cb2 = _batcher(params, cfg, p_len + max_new + 8, chunk,
                       policy=policy, slots=slots)
        tps, toks, _ = _hybrid_tokens_per_s(cb2, prompts, max_new)
        for _ in range(trials - 1):
            cb2.finished.clear()
            t2, _, _ = _hybrid_tokens_per_s(cb2, prompts, max_new)
            tps = max(tps, t2)
        rows.append(dict(kind="policy", arch="llama2-7b(reduced)",
                         policy=name, token_budget=budget, chunk_size=chunk,
                         slots=slots, prompt_len=prompt_len, ttft_s=ttft,
                         prefill_steps=steps, requests=n_req,
                         hybrid_prompt_len=p_len, tokens_per_s=tps))
        print(f"[serving] policy={name:12s} budget={budget}: TTFT "
              f"{ttft * 1e3:8.1f} ms ({steps} iters), hybrid {tps:8.1f} "
              f"tok/s")
    fcfs, tb = rows
    tb["ttft_speedup_vs_fcfs"] = fcfs["ttft_s"] / tb["ttft_s"]
    tb["throughput_vs_fcfs"] = tb["tokens_per_s"] / fcfs["tokens_per_s"]
    print(f"[serving] token_budget vs fcfs at budget={budget}: "
          f"TTFT {tb['ttft_speedup_vs_fcfs']:.2f}x, tokens/s "
          f"{tb['throughput_vs_fcfs']:.2f}x")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(Path(__file__).parent
                                         / "BENCH_serving.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps (CI smoke)")
    args = ap.parse_args(argv)
    ttft = bench_ttft(smoke=args.smoke)
    best = max(r["speedup_vs_token_by_token"] for r in ttft)
    print(f"[serving] best TTFT speedup over token-by-token: {best:.1f}x")
    result = dict(
        platform=jax.default_backend(),
        prompt_len=ttft[0]["prompt_len"],
        best_ttft_speedup=best,
        rows=ttft + bench_hybrid_throughput(smoke=args.smoke)
        + bench_policies(smoke=args.smoke),
    )
    Path(args.out).write_text(json.dumps(result, indent=2))
    print(f"[serving] wrote {args.out}")


if __name__ == "__main__":
    main()
