"""Benchmark harness: one function per paper table. Emits
``name,us_per_call,derived`` CSV rows (also mirrored to stdout)."""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list of table substrings to run")
    args = ap.parse_args(argv)

    from benchmarks import tables
    from benchmarks.common import ROWS

    runs = [
        ("table1", tables.run_table1_perplexity),
        ("table2", tables.run_table2_downstream),
        ("table3", tables.run_table3_fractional),
        ("table4", tables.run_table4_throughput),
        ("table5", tables.run_table5_overhead),
        ("table6", tables.run_ablation_bit_allocation),
        ("table7", tables.run_ablation_lattice),
        ("table8", tables.run_ablation_companding),
        ("table9", tables.run_ablation_group_size),
        ("table11", tables.run_ablation_calibration_size),
        ("table12", tables.run_ablation_rounding),
    ]
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    for name, fn in runs:
        if only and not any(o in name for o in only):
            continue
        print(f"# --- {name}: {fn.__doc__.splitlines()[0]}", flush=True)
        fn()
    return None


if __name__ == "__main__":
    main()
