#!/usr/bin/env python
"""Repo lint: no new bare ``print(`` / ``time.time()`` in ``src/repro``.

``repro.serving.metrics`` is the sanctioned timing + CLI-logging surface
(``Timer`` for spans, ``log_event`` for structured ``[tag] k=v`` lines,
histograms for distributions); ``repro.serving.trace`` owns the wall-clock
``ts`` stamp of the JSONL event log.  Everything else should route through
them — this lint pins the existing CLI surfaces at their current counts so
new ad-hoc prints / timers fail CI instead of accreting.

Run:  python scripts/lint_timing.py        (exit 1 on violation)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src" / "repro"

# the telemetry modules themselves: log_event's print and TraceLog's
# wall-clock ts stamp live here by design
EXEMPT = {"serving/metrics.py", "serving/trace.py"}

# existing surfaces, pinned at their current counts — shrinking is fine,
# growing fails.  print: CLI drivers' non-timing output (tables, stream
# echo); time.time: the checkpoint manifest's wall-clock stamp (a real
# timestamp, not a duration — perf_counter would be wrong there).
ALLOWED = {
    "launch/roofline.py": {"print": 2, "time.time": 0},
    "launch/dryrun.py": {"print": 1, "time.time": 0},
    "launch/serve.py": {"print": 7, "time.time": 0},
    "ckpt/manager.py": {"print": 0, "time.time": 1},
}

PATTERNS = {
    "print": re.compile(r"(?<![\w.])print\("),
    "time.time": re.compile(r"\btime\.time\(\)"),
}


def main() -> int:
    bad = []
    for path in sorted(SRC.rglob("*.py")):
        rel = path.relative_to(SRC).as_posix()
        if rel in EXEMPT:
            continue
        text = path.read_text(encoding="utf-8")
        budget = ALLOWED.get(rel, {})
        for name, pat in PATTERNS.items():
            n = len(pat.findall(text))
            cap = budget.get(name, 0)
            if n > cap:
                bad.append(f"src/repro/{rel}: {n} bare {name}( calls "
                           f"(allowed {cap}) — use repro.serving.metrics."
                           f"{'log_event' if name == 'print' else 'Timer'} "
                           "instead")
    if bad:
        print("\n".join(["[lint_timing] FAIL:"] + [f"  {b}" for b in bad]))
        return 1
    print("[lint_timing] ok: no stray print()/time.time() in src/repro")
    return 0


if __name__ == "__main__":
    sys.exit(main())
