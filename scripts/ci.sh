#!/usr/bin/env bash
# Tier-1 verify entrypoint: the one command CI and humans run.
#   ./scripts/ci.sh            -> tier-1 (fail-fast, mirrors ROADMAP.md)
#   ./scripts/ci.sh tests/foo  -> forward extra pytest args
#
# Note: with -x the run stops at the first failure; in containers where
# tests/test_sharding.py::test_compressed_pod_psum_subprocess fails
# (pre-existing, needs jax.shard_map), the later test files are skipped.
# For full coverage run:
#   ./scripts/ci.sh --deselect tests/test_sharding.py::test_compressed_pod_psum_subprocess
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
