#!/usr/bin/env bash
# Tier-1 verify entrypoint: the one command CI and humans run.
#   ./scripts/ci.sh            -> tier-1 (fail-fast, mirrors ROADMAP.md)
#   ./scripts/ci.sh tests/foo  -> forward extra pytest args
#
# After the test suite, both benchmark drivers run one smoke invocation
# (tiny shapes, interpret-mode kernels off-TPU) so they can't silently rot;
# smoke JSON goes to a scratch dir and never overwrites the tracked
# BENCH_*.json perf-trajectory files.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.engine \
    --smoke --out "$SMOKE_DIR/BENCH_engine.json"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.kvcache \
    --smoke --out "$SMOKE_DIR/BENCH_kvcache.json"
echo "[ci] benchmark smoke OK"
