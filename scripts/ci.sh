#!/usr/bin/env bash
# Tier-1 verify entrypoint: the one command CI and humans run.
#   ./scripts/ci.sh            -> tier-1 (fail-fast, mirrors ROADMAP.md)
#   ./scripts/ci.sh tests/foo  -> forward extra pytest args
#
# After the test suite, both benchmark drivers run one smoke invocation
# (tiny shapes, interpret-mode kernels off-TPU) so they can't silently rot;
# smoke JSON goes to a scratch dir and never overwrites the tracked
# BENCH_*.json perf-trajectory files.
set -euo pipefail
cd "$(dirname "$0")/.."
# REPRO_SKIP_TP_SUBPROCESS: the dedicated forced-8-device step below covers
# the TP suite, so the tier-1 pass skips test_tp_engine's self-re-running
# subprocess test instead of paying for the suite twice.  A plain
# `pytest -x -q` outside ci.sh still runs it.
REPRO_SKIP_TP_SUBPROCESS=1 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Forced-8-device CPU pass: the sharding rules + tensor-parallel engine run
# against a real (host-emulated) multi-device mesh so the sharded path
# cannot regress silently.  (On 1 device the TP suite only runs via its own
# subprocess test; here it runs in-process on all 8.)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_sharding.py tests/test_tp_engine.py

# Forced-8-device chunked-prefill + sampled-serving TP parity (chunk_step
# with a mesh, the chunked scheduler, and in-graph sampling over sharded
# weights); filtered so the single-device tests don't run twice.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_chunked.py tests/test_serving_api.py -k "tp and not subprocess"

# Fused paged-attention: force the pallas backend (interpret mode off-TPU)
# through the kernel + engine parity suite so the fused path can't rot
# behind the platform default.
REPRO_ATTN_BACKEND=pallas \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_attention_kernel.py -k "not subprocess"

# Static analysis gate: rules R1-R8 (timing/logging hygiene, host syncs,
# recompile hazards, Pallas tile lint, sharding completeness, dtype
# hygiene, frozen-config mutation, untraced RNG) against the checked-in
# (empty) baseline.  Includes R5's semantic pass over every config's
# param tree.  Exit 1 on any new finding.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis

SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT

# ServingEngine smoke: the front door end to end — EngineConfig, in-graph
# sampling (temperature/top-k/seed), streamed TokenEvents, stop tokens, the
# Sarathi token-budget packer, and the telemetry subsystem (metrics snapshot
# + per-iteration trace log).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
    --requests 3 --batch 2 --prompt-len 9 --max-new 4 --chunk-size 4 \
    --policy token_budget --token-budget 6 \
    --temperature 0.8 --top-k 8 --seed 0 --stop-token 3 --stream \
    --metrics-json "$SMOKE_DIR/metrics.json" \
    --trace-log "$SMOKE_DIR/trace.jsonl"
# metrics smoke: the snapshot must carry the core serving series and a
# non-empty TTFT histogram (every request got a first token)
python - "$SMOKE_DIR/metrics.json" "$SMOKE_DIR/trace.jsonl" <<'PYEOF'
import json, sys
snap = json.load(open(sys.argv[1]))
for key, name in (("counters", "serving_requests_submitted_total"),
                  ("counters", "serving_requests_finished_total"),
                  ("counters", "serving_tokens_generated_total"),
                  ("counters", "serving_compile_events_total"),
                  ("histograms", "serving_ttft_seconds"),
                  ("histograms", "serving_queue_wait_seconds"),
                  ("histograms", "serving_inter_token_seconds"),
                  ("gauges", "serving_slab_padded_fraction")):
    assert name in snap[key], f"metrics snapshot missing {key}/{name}"
ttft = snap["histograms"]["serving_ttft_seconds"][""]
assert ttft["count"] == 3, f"expected 3 TTFT samples, got {ttft['count']}"
n = sum(1 for _ in open(sys.argv[2]))
assert n > 0, "trace log is empty"
print(f"[ci] metrics smoke OK ({ttft['count']} TTFT samples, "
      f"{n} trace records)")
PYEOF

# Runtime-sanitizer smoke: debug_checks=on serving across ALL cache kinds
# (in-graph checkify assertions + allocator aliasing + recompile monitor
# must pass clean on every KV layout, quantized blocks included).
for kind in dense paged paged_q8 paged_q8c paged_glvq; do
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
        --requests 2 --batch 2 --prompt-len 7 --max-new 3 --chunk-size 4 \
        --cache "$kind" --debug-checks --no-metrics
done
echo "[ci] debug_checks smoke OK (all cache kinds)"

# GLVQ lattice-coded KV smoke on BOTH kv backends (the xla fallback and the
# Pallas kernels in interpret mode) so the packed-code append/gather path
# can't rot behind the platform default.
for be in xla pallas; do
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
        --requests 2 --batch 2 --prompt-len 7 --max-new 3 --chunk-size 4 \
        --cache paged_glvq --kv-backend "$be" --debug-checks --no-metrics
done
echo "[ci] paged_glvq smoke OK (both kv backends)"

# Prefix-cache smoke: radix sharing + copy-on-write + refcounted aliasing
# under the sanitizer, across every paged cache kind ("dense" exercises the
# flag being a validated no-op).  --shared-prefix guarantees cache hits.
for kind in dense paged paged_q8 paged_q8c paged_glvq; do
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
        --requests 4 --batch 2 --prompt-len 24 --max-new 3 --chunk-size 4 \
        --cache "$kind" --kv-block-size 8 --prefix-cache --shared-prefix 18 \
        --debug-checks --no-metrics
done
echo "[ci] prefix-cache smoke OK (all cache kinds)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.engine \
    --smoke --out "$SMOKE_DIR/BENCH_engine.json"
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.engine \
    --smoke --tp 2 --out "$SMOKE_DIR/BENCH_engine_tp.json"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.kvcache \
    --smoke --out "$SMOKE_DIR/BENCH_kvcache.json"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serving \
    --smoke --out "$SMOKE_DIR/BENCH_serving.json"
# attention smoke also asserts the fused-vs-unfused modeled-HBM-bytes bar
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.attention \
    --smoke --out "$SMOKE_DIR/BENCH_attention.json"
echo "[ci] benchmark smoke OK"
