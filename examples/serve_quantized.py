"""End-to-end serving driver: ServingEngine continuous batching over
GLVQ-quantized weights (streaming per-layer dequantization, Sec 3.4) with
per-request in-graph sampling.

Run:  PYTHONPATH=src python examples/serve_quantized.py --quant-bits 4
Sampled + streamed:
      PYTHONPATH=src python examples/serve_quantized.py --quant-bits 4 \
          --temperature 0.8 --top-k 40 --seed 0 --stream
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
