"""End-to-end serving driver: batched-request decode loop over
GLVQ-quantized weights (streaming per-layer dequantization, Sec 3.4).

Run:  PYTHONPATH=src python examples/serve_quantized.py --quant-bits 4
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
