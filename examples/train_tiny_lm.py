"""End-to-end training driver: train a small LM on the synthetic Markov
language with WSD schedule, checkpoints and automatic resume.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
(`--arch` accepts any of the 10 assigned architectures; reduced configs.)
"""
from repro.launch.train import main

if __name__ == "__main__":
    main()
