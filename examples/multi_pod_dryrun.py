"""Lower + compile a production cell without hardware (the dry-run).

Run:  PYTHONPATH=src python examples/multi_pod_dryrun.py \
          --arch qwen3-1.7b --shape decode_32k --mesh multi --quant-bits 2
"""
from repro.launch.dryrun import main

if __name__ == "__main__":
    raise SystemExit(main())
