"""The paper's full pipeline at laptop scale: train -> calibrate -> GLVQ
quantize at 2/3/4 bits -> compare perplexity against RTN / GPTQ /
fixed-lattice (Tables 1 & 7 protocol).

Run:  PYTHONPATH=src python examples/quantize_and_eval.py
"""
import sys
sys.path.insert(0, ".")

from benchmarks.common import tiny_trained_lm, calibration_h, eval_ppl, \
    quantize_and_ppl

cfg, params = tiny_trained_lm(steps=80)
print(f"trained tiny llama ({cfg.n_layers}L d={cfg.d_model}); "
      f"fp32 ppl = {eval_ppl(params, cfg):.3f}")
for bits in (4, 3, 2):
    row = [f"{bits}-bit:"]
    for method in ("glvq", "glvq+", "rtn", "gptq", "fixed-lattice"):
        ppl, _ = quantize_and_ppl(method, bits)
        row.append(f"{method}={ppl:.2f}")
    print("  ".join(row))
