"""Quickstart: GLVQ-quantize one weight matrix and inspect the pieces.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import GLVQConfig, quantize_layer, dequantize_layer, sdba
from repro.core.baselines import rtn_quantize

rng = np.random.default_rng(0)

# A heavy-tailed "LLM-like" weight [in=512, out=256] + calibration inputs
W = jnp.asarray(rng.standard_t(df=3, size=(512, 256)) * 0.02, jnp.float32)
X = jnp.asarray(rng.normal(size=(512, 1024)), jnp.float32)
H = X @ X.T                                   # calibration second moment

# 1) salience-determined bit allocation (Sec 3.1): mean exactly 2 bits
bits = sdba(W, H, group_size=128, n_bits=2)
print("per-group bits:", bits, "mean:", bits.mean())

# 2) learn group lattices + companding (Sec 3.2/3.3, Alg. 1)
cfg = GLVQConfig(d=8, bits=2, iters=100, lr=1e-2)
q = quantize_layer(W, H, cfg, jnp.asarray(bits))
print("codes:", q["codes"].shape, q["codes"].dtype,
      "| G:", q["g"].shape, "| mu:", np.asarray(q["mu"]).round(1))

# 3) decode and compare against round-to-nearest at the same rate
W_glvq = dequantize_layer(q, cfg)
W_rtn = rtn_quantize(W, 2)
obj = lambda Wh: float(jnp.sum(((W - Wh).T @ H @ (W - Wh)).diagonal()))
print(f"calibration-weighted error  GLVQ: {obj(W_glvq):.2f}   RTN: {obj(W_rtn):.2f}")
