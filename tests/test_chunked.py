"""Chunked prefill: the unified variable-width serving step.

Acceptance bar: chunked prefill (``registry.chunk_step`` driving T tokens
per slot per engine iteration) produces the SAME tokens as the
token-by-token oracle on every family x cache_kind, including chunks that
end mid-block, uneven per-slot lengths, idle slots, sliding-window rings,
and the tensor-parallel path.  Plus the satellite guarantees: fused q/k/v
dispatch, layer-private sliding-window pool geometry, and the scheduler's
oversized-prompt rejection.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import kvcache
from repro.serving.scheduler import ContinuousBatcher, Request

ALL_KINDS = kvcache.CACHE_KINDS               # dense | paged | paged_q8[c]
FAMILIES = ["llama2-7b", "mamba2-1.3b", "recurrentgemma-9b"]

S_CACHE, BLOCK = 32, 4
CHUNK = 5                                     # ends mid-block (5 % 4 != 0)


def _params(arch, seed=0):
    cfg = reduced(get_config(arch))
    return cfg, registry.init_params(jax.random.PRNGKey(seed), cfg)


def _oracle_logits(params, cfg, tokens, kind):
    """Token-by-token decode of one B=1 stream -> logits [T, V]."""
    cache = registry.cache_init(cfg, 1, S_CACHE, jnp.float32,
                                cache_kind=kind, block_size=BLOCK)
    if kind != "dense":
        cache["table"] = kvcache.static_table(1, -(-S_CACHE // BLOCK))
    outs = []
    for t, tok in enumerate(tokens):
        lg, cache = registry.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32),
            jnp.asarray([t], jnp.int32), cfg, dtype=jnp.float32,
            cache_kind=kind, s_cache=S_CACHE)
        outs.append(np.asarray(lg[0]))
    return np.stack(outs)


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("arch", FAMILIES)
def test_chunk_step_matches_token_by_token(arch, kind):
    """Feed two staggered prompts through fixed-width T=5 chunks (uneven
    lens, mid-block chunk ends, an idle tail for the short slot) and compare
    each chunk-final logit row to the token-by-token oracle."""
    cfg, params = _params(arch)
    rng = np.random.default_rng(5)
    streams = [list(map(int, rng.integers(1, cfg.vocab, n)))
               for n in (12, 9)]
    refs = [_oracle_logits(params, cfg, s, kind) for s in streams]

    b = len(streams)
    cache = registry.cache_init(cfg, b, S_CACHE, jnp.float32,
                                cache_kind=kind, block_size=BLOCK)
    if kind != "dense":
        cache["table"] = kvcache.static_table(b, -(-S_CACHE // BLOCK))
    step = jax.jit(lambda p, c, t, pos, lens: registry.chunk_step(
        p, c, t, pos, lens, cfg, dtype=jnp.float32, cache_kind=kind,
        s_cache=S_CACHE))
    cursors = [0, 0]
    while any(c < len(s) for c, s in zip(cursors, streams)):
        toks = np.zeros((b, CHUNK), np.int32)
        lens = np.zeros((b,), np.int32)
        poss = np.zeros((b,), np.int32)
        for i, s in enumerate(streams):
            take = min(CHUNK, len(s) - cursors[i])
            if take > 0:
                toks[i, :take] = s[cursors[i]:cursors[i] + take]
            lens[i] = max(take, 0)
            poss[i] = cursors[i]
        logits, cache = step(params, cache, jnp.asarray(toks),
                             jnp.asarray(poss), jnp.asarray(lens))
        logits = np.asarray(logits)
        for i in range(b):
            if lens[i] == 0:
                continue                       # idle slot: garbage logits
            cursors[i] += int(lens[i])
            ref = refs[i][cursors[i] - 1]      # oracle at the chunk's last tok
            tol = 1e-5 * max(np.abs(ref).max(), 1.0)
            np.testing.assert_allclose(logits[i], ref, rtol=1e-5, atol=tol)
            assert int(np.argmax(logits[i])) == int(np.argmax(ref)), \
                (arch, kind, i, cursors[i])


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_chunk_crossing_window_ring(kind):
    """Hybrid family with chunks filling the sliding-window ring: chunk ends
    that straddle ring wrap-around must still match the oracle."""
    cfg, params = _params("recurrentgemma-9b", seed=3)
    assert cfg.window == 8                     # reduced() caps the window
    rng = np.random.default_rng(9)
    stream = list(map(int, rng.integers(1, cfg.vocab, 21)))  # 2.6 rings
    ref = _oracle_logits(params, cfg, stream, kind)
    cache = registry.cache_init(cfg, 1, S_CACHE, jnp.float32,
                                cache_kind=kind, block_size=BLOCK)
    if kind != "dense":
        cache["table"] = kvcache.static_table(1, -(-S_CACHE // BLOCK))
    step = jax.jit(lambda p, c, t, pos, lens: registry.chunk_step(
        p, c, t, pos, lens, cfg, dtype=jnp.float32, cache_kind=kind,
        s_cache=S_CACHE))
    t_chunk = 7                                # < window, wraps mid-chunk
    cursor = 0
    while cursor < len(stream):
        take = min(t_chunk, len(stream) - cursor)
        toks = np.zeros((1, t_chunk), np.int32)
        toks[0, :take] = stream[cursor:cursor + take]
        logits, cache = step(params, cache, jnp.asarray(toks),
                             jnp.asarray([cursor], jnp.int32),
                             jnp.asarray([take], jnp.int32))
        cursor += take
        r = ref[cursor - 1]
        np.testing.assert_allclose(np.asarray(logits[0]), r, rtol=1e-5,
                                   atol=1e-5 * max(np.abs(r).max(), 1.0))


def test_chunk_exceeding_ring_raises():
    cfg, params = _params("recurrentgemma-9b", seed=3)
    cache = registry.cache_init(cfg, 1, S_CACHE, jnp.float32)
    toks = jnp.zeros((1, cfg.window + 1), jnp.int32)
    with pytest.raises(ValueError, match="ring"):
        registry.chunk_step(params, cache, toks,
                            jnp.zeros((1,), jnp.int32),
                            jnp.asarray([cfg.window + 1], jnp.int32), cfg,
                            dtype=jnp.float32)


# ---------------------------------------------------------------------------
# scheduler: hybrid chunked batching end-to-end
# ---------------------------------------------------------------------------

def _sequential_generate(params, cfg, prompt, max_new, s_cache=32):
    cache = registry.cache_init(cfg, 1, s_cache, jnp.float32)
    out = []
    for pos in range(len(prompt) + max_new - 1):
        t = prompt[pos] if pos < len(prompt) else out[-1]
        logits, cache = registry.decode_step(
            params, cache, jnp.asarray([t], jnp.int32),
            jnp.asarray([pos], jnp.int32), cfg, dtype=jnp.float32)
        if pos >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
        if len(out) >= max_new:
            break
    return out


@pytest.mark.parametrize("arch,kind", [
    ("llama2-7b", "dense"), ("llama2-7b", "paged_q8"),
    ("mamba2-1.3b", "dense"), ("recurrentgemma-9b", "paged")])
def test_scheduler_chunked_matches_token_by_token(arch, kind):
    """ContinuousBatcher with chunked prefill (hybrid prefill+decode
    iterations, slot churn) must emit bit-identical tokens to both the
    chunk_size=1 baseline and the one-request-at-a-time reference."""
    cfg, params = _params(arch, seed=1)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, n)))
               for n in (11, 3, 7, 14, 5)]
    max_new = 4

    def run(chunk):
        cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32,
                               dtype=jnp.float32, cache_kind=kind,
                               block_size=4, chunk_size=chunk)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=max_new))
        done = cb.run()
        return {i: r.tokens for i, r in done.items()}

    chunked = run(8)
    assert chunked == run(1)
    if kind == "dense":
        ref = {i: _sequential_generate(params, cfg, p, max_new)
               for i, p in enumerate(prompts)}
        assert chunked == ref


def test_submit_rejects_oversized_prompt():
    """A prompt >= s_cache used to be silently 'finished' mid-prompt by the
    retire check and returned garbage; now it's rejected at submit."""
    cfg, params = _params("llama2-7b")
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=16)
    with pytest.raises(ValueError, match="s_cache"):
        cb.submit(Request(rid=0, prompt=list(range(1, 17)), max_new=2))
    cb.submit(Request(rid=1, prompt=list(range(1, 16)), max_new=2))
    done = cb.run()                            # 15-token prompt still fits
    assert done[1].tokens and len(done[1].tokens) >= 1


def test_scheduler_clamps_chunk_to_window():
    cfg, params = _params("recurrentgemma-9b")
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32, chunk_size=64)
    assert cb.chunk == min(cfg.window, 32)


# ---------------------------------------------------------------------------
# satellite: fused q/k/v dispatch (one engine call for the shared slab)
# ---------------------------------------------------------------------------

def test_qkv_projections_fuse_into_one_dispatch(monkeypatch):
    """The q/k/v projections of an attention block must reach the engine as
    ONE fused column-group call (activations streamed once) instead of three
    separate quant_matmul dispatches."""
    from repro.core import qtensor
    from repro.core.glvq import GLVQConfig
    from repro.core.quantized import quantize_param_tree
    from repro.kernels import ops
    from repro.models import lm

    cfg, params = _params("llama2-7b")
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)

    calls = {"cols": [], "single": 0}
    real_cols = ops.quant_matmul_cols
    real_single = ops.quant_matmul

    def spy_cols(x, parts, **kw):
        calls["cols"].append(len(parts))
        return real_cols(x, parts, **kw)

    def spy_single(x, payload, meta, **kw):
        calls["single"] += 1
        return real_single(x, payload, meta, **kw)

    def run():
        calls["cols"], calls["single"] = [], 0
        cache = registry.cache_init(cfg, 2, 8, jnp.float32)
        lm.decode_step(qparams, cache, tok, pos, cfg, dtype=jnp.float32,
                       qmeta=qmeta, backend="xla_decode")
        return list(calls["cols"]), calls["single"]

    monkeypatch.setattr(ops, "quant_matmul_cols", spy_cols)
    monkeypatch.setattr(ops, "quant_matmul", spy_single)
    fused_cols, fused_single = run()
    # llama: one scanned attn unit -> exactly one fused call of 3 payloads
    assert fused_cols == [3]
    # now disable fusion and confirm the same step costs 3 extra dispatches
    monkeypatch.setattr(
        qtensor, "matmul_cols",
        lambda ws, x, out_dtype=None: tuple(
            w.matmul(x, out_dtype=out_dtype) for w in ws))
    plain_cols, plain_single = run()
    assert plain_cols == []
    assert plain_single == fused_single + 3


# ---------------------------------------------------------------------------
# satellite: layer-private sliding-window pool geometry
# ---------------------------------------------------------------------------

def test_local_window_pools_are_window_sized():
    """Sliding-window layers size their paged pools to ceil(ring/bs) blocks
    per slot (+ scratch) instead of the global pool depth, reclaiming HBM on
    hybrid families; global layers keep the shared allocator geometry."""
    cfg, _ = _params("recurrentgemma-9b")
    slots, s_cache, bs = 2, 32, 4
    ring = min(cfg.window, s_cache)
    nb_local = -(-ring // bs)
    layout = kvcache.PageLayout.plan(s_cache, slots, bs)
    cache = registry.cache_init(cfg, slots, s_cache, jnp.float32,
                                cache_kind="paged_q8", block_size=bs,
                                num_blocks=layout.num_blocks)
    kinds = list(cfg.scan_unit)
    local_i = kinds.index("attn_local")
    local = cache["blocks"][local_i]            # stacked [R, ...]
    # layer-private pool: 1 + slots * ceil(ring/bs) blocks, baked-in table
    assert local["kp"].shape[1] == 1 + slots * nb_local
    assert local["lt"].shape == (cfg.n_repeats, slots, nb_local)
    assert np.array_equal(
        np.asarray(local["lt"][0]),
        1 + nb_local * np.arange(slots)[:, None] + np.arange(nb_local)[None])
    # byte accounting: the ring pool holds ring-many positions per slot
    # (+ scratch), NOT the global worst-case depth
    global_depth = layout.num_blocks
    assert global_depth == 1 + slots * (s_cache // bs)
    per_block = bs * cfg.n_kv_heads * cfg.hd          # int8 codes
    assert local["kp"].nbytes == \
        cfg.n_repeats * (1 + slots * nb_local) * per_block
    reclaimed = (global_depth - (1 + slots * nb_local)) * per_block
    assert reclaimed > 0
    # analytic accounting matches the static ring ownership: a hybrid
    # family's local-layer bytes never scale with seq_len (its only attn
    # layers are sliding-window, so paged bytes are seq-independent up to
    # the ring)
    short = kvcache.cache_bytes(cfg, "paged_q8", 1, s_cache, bs)
    full = kvcache.cache_bytes(cfg, "paged_q8", ring, s_cache, bs)
    assert short == full
    per_pos = 2 * (cfg.n_kv_heads * cfg.hd + 2 * cfg.n_kv_heads)
    n_local = sum(k == "attn_local" for k in cfg.scan_unit) * cfg.n_repeats
    assert full == n_local * nb_local * bs * per_pos \
        + 4 * (-(-s_cache // bs))                      # + int32 table row
    # dense-attention families keep the shared geometry untouched
    cfg2, _ = _params("llama2-7b")
    cache2 = registry.cache_init(cfg2, slots, s_cache, jnp.float32,
                                 cache_kind="paged_q8", block_size=bs,
                                 num_blocks=layout.num_blocks)
    assert cache2["blocks"][0]["kp"].shape[1] == global_depth
    assert "lt" not in cache2["blocks"][0]


# ---------------------------------------------------------------------------
# tensor-parallel chunked prefill (8-device mesh; subprocess fallback)
# ---------------------------------------------------------------------------

_multidev = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); covered by the subprocess test on 1 device")


@_multidev
def test_tp_chunk_step_matches_meshless():
    """chunk_step(mesh=...) at T>1 (prefill-sized M) must reproduce the
    meshless logits — the sharded matmul path composes with chunking."""
    from repro.core.glvq import GLVQConfig
    from repro.core.quantized import quantize_param_tree
    cfg, params = _params("llama2-7b")
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 6)), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    lens = jnp.asarray([6, 4], jnp.int32)

    def logits(mesh):
        cache = registry.cache_init(cfg, 2, 16, jnp.float32)
        lg, _ = jax.jit(lambda p, c: registry.chunk_step(
            p, c, toks, pos, lens, cfg, dtype=jnp.float32, qmeta=qmeta,
            backend="xla_decode", mesh=mesh))(qparams, cache)
        return np.asarray(lg)

    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    ref = logits(None)
    np.testing.assert_allclose(logits(mesh), ref, rtol=1e-4, atol=1e-4)


@_multidev
def test_tp_scheduler_chunked_matches_meshless():
    """Chunked prefill + TP + paged_q8 cache: token-identical end to end."""
    from repro.core.glvq import GLVQConfig
    from repro.core.quantized import quantize_param_tree
    cfg, params = _params("llama2-7b", seed=1)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10]]

    def run(mesh):
        cb = ContinuousBatcher(qparams, cfg, slots=2, s_cache=16,
                               dtype=jnp.float32, qmeta=qmeta,
                               backend="xla_decode", cache_kind="paged_q8",
                               block_size=4, chunk_size=4, mesh=mesh)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=3))
        return {i: r.tokens for i, r in cb.run().items()}

    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    assert run(mesh) == run(None)


def test_tp_chunked_forced_8dev_subprocess():
    """Under the plain tier-1 run (1 device) re-run the TP chunk tests on a
    forced 8-device CPU so the sharded chunked path is always exercised."""
    if jax.device_count() >= 8:
        pytest.skip("multi-device host: the direct tests above already ran")
    if os.environ.get("REPRO_SKIP_TP_SUBPROCESS"):
        pytest.skip("REPRO_SKIP_TP_SUBPROCESS set: the caller runs the "
                    "forced-8-device suite itself (scripts/ci.sh)")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "tp and not subprocess", "-p", "no:cacheprovider"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=1800)
    assert out.returncode == 0, (out.stdout[-3000:] + out.stderr[-3000:])
