"""The serving API redesign: EngineConfig, in-graph per-request sampling,
streaming, and pluggable scheduler policies.

Acceptance bar: temperature=0 in-graph sampling equals the PR-4 greedy path
(host argmax on decode_step logits) token-for-token on every family x
cache_kind; a fixed seed reproduces the same stream across chunk widths,
packing policies, and the TP mesh; TokenBudgetPolicy compiles a bounded
program-shape family and respects its budget; the PR-4 loose-kwarg call
sites keep working through the deprecation shim.
"""
import os
import subprocess
import sys
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import (ContinuousBatcher, EngineConfig, FCFSPolicy,
                           Request, SamplingParams, ServingEngine,
                           TokenBudgetPolicy, kvcache)
from repro.serving.policy import default_ladder
from repro.serving.sampling import sample_tokens

ALL_KINDS = kvcache.CACHE_KINDS               # dense | paged | paged_q8[c]
FAMILIES = ["llama2-7b", "mamba2-1.3b", "recurrentgemma-9b"]

S_CACHE, BLOCK, CHUNK = 32, 4, 5


def _params(arch, seed=0):
    cfg = reduced(get_config(arch))
    return cfg, registry.init_params(jax.random.PRNGKey(seed), cfg)


def _ecfg(**kw):
    kw.setdefault("dtype", jnp.float32)
    kw.setdefault("s_cache", S_CACHE)
    kw.setdefault("slots", 2)
    kw.setdefault("block_size", BLOCK)
    return EngineConfig(**kw)


def _oracle_generate(params, cfg, prompt, max_new, kind="dense"):
    """The PR-4 greedy path: token-by-token decode_step + HOST argmax."""
    cache = registry.cache_init(cfg, 1, S_CACHE, jnp.float32,
                                cache_kind=kind, block_size=BLOCK)
    if kind != "dense":
        cache["table"] = kvcache.static_table(1, -(-S_CACHE // BLOCK))
    out = []
    for pos in range(len(prompt) + max_new - 1):
        t = prompt[pos] if pos < len(prompt) else out[-1]
        logits, cache = registry.decode_step(
            params, cache, jnp.asarray([t], jnp.int32),
            jnp.asarray([pos], jnp.int32), cfg, dtype=jnp.float32,
            cache_kind=kind, s_cache=S_CACHE)
        if pos >= len(prompt) - 1:
            out.append(int(np.argmax(np.asarray(logits[0]))))
        if len(out) >= max_new:
            break
    return out


# ---------------------------------------------------------------------------
# in-graph sampling parity: temperature=0 == the PR-4 greedy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("arch", FAMILIES)
def test_greedy_in_graph_matches_host_argmax_oracle(arch, kind):
    """Default SamplingParams (temperature=0) through the new engine must be
    bit-for-bit the old host-side argmax, for every family x cache_kind,
    under chunked prefill with uneven prompt lengths."""
    cfg, params = _params(arch)
    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, n))) for n in (6, 4)]
    max_new = 3
    refs = [_oracle_generate(params, cfg, p, max_new, kind) for p in prompts]

    eng = ServingEngine(params, cfg,
                        _ecfg(cache_kind=kind, chunk_size=CHUNK))
    hs = [eng.submit(p, SamplingParams(max_tokens=max_new)) for p in prompts]
    eng.run()
    for h, ref in zip(hs, refs):
        assert h.done and h.done_reason == "length"
        assert h.tokens == ref, (arch, kind, h.tokens, ref)


def test_engine_config_matches_loose_kwargs():
    """registry.chunk_step(engine=EngineConfig(...)) and the legacy loose
    kwargs are the same program."""
    cfg, params = _params("llama2-7b")
    cache0 = registry.cache_init(cfg, 2, S_CACHE, jnp.float32,
                                 cache_kind="paged", block_size=BLOCK)
    cache0["table"] = kvcache.static_table(2, -(-S_CACHE // BLOCK))
    toks = jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    lens = jnp.asarray([3, 2], jnp.int32)
    lg_new, _ = registry.chunk_step(
        params, cache0, toks, pos, lens, cfg,
        engine=EngineConfig(dtype=jnp.float32, cache_kind="paged",
                            s_cache=S_CACHE))
    lg_old, _ = registry.chunk_step(
        params, cache0, toks, pos, lens, cfg, dtype=jnp.float32,
        cache_kind="paged", s_cache=S_CACHE)
    np.testing.assert_array_equal(np.asarray(lg_new), np.asarray(lg_old))


def test_engine_config_rejects_mixed_spellings():
    cfg, params = _params("llama2-7b")
    cache = registry.cache_init(cfg, 1, 8, jnp.float32)
    with pytest.raises(TypeError, match="not both"):
        registry.decode_step(params, cache, jnp.zeros((1,), jnp.int32),
                             jnp.zeros((1,), jnp.int32), cfg,
                             engine=EngineConfig(dtype=jnp.float32),
                             dtype=jnp.float32)
    with pytest.raises(TypeError, match="geometry"):
        registry.cache_init(cfg, 1, 8, engine=_ecfg())


# ---------------------------------------------------------------------------
# sample_tokens unit behavior
# ---------------------------------------------------------------------------

def test_sample_tokens_greedy_and_degenerate_filters_are_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 97)), jnp.float32)
    ref = np.argmax(np.asarray(logits), -1)
    z = jnp.zeros((5,), jnp.int32)
    seeds = jnp.arange(5, dtype=jnp.int32)
    ones = jnp.ones((5,), jnp.float32)
    # temperature 0 -> exact argmax
    out = sample_tokens(logits, seeds, z, jnp.zeros((5,), jnp.float32), z,
                        ones)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # top_k=1 forces the argmax even at temperature > 0
    out = sample_tokens(logits, seeds, z, 2.0 * ones,
                        jnp.ones((5,), jnp.int32), ones)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # a vanishing top_p keeps only the most likely token
    out = sample_tokens(logits, seeds, z, 2.0 * ones, z, 1e-6 * ones)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_sample_tokens_respects_top_k_support_and_is_deterministic():
    rng = np.random.default_rng(1)
    row = rng.normal(size=(64,)).astype(np.float32)
    b = 256
    logits = jnp.asarray(np.tile(row, (b, 1)))
    seeds = jnp.full((b,), 3, jnp.int32)
    idx = jnp.arange(b, dtype=jnp.int32)          # one draw per token index
    temps = jnp.full((b,), 1.5, jnp.float32)
    ks = jnp.full((b,), 5, jnp.int32)
    ps = jnp.ones((b,), jnp.float32)
    out = np.asarray(sample_tokens(logits, seeds, idx, temps, ks, ps))
    top5 = set(np.argsort(-row)[:5].tolist())
    assert set(out.tolist()) <= top5
    assert len(set(out.tolist())) > 1             # it does actually sample
    again = np.asarray(sample_tokens(logits, seeds, idx, temps, ks, ps))
    np.testing.assert_array_equal(out, again)     # same key -> same draw


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)


# ---------------------------------------------------------------------------
# seeded sampling: reproducible across chunk widths / policies / engines
# ---------------------------------------------------------------------------

def _sampled_run(params, cfg, prompts, sp, chunk, policy=None, kind="dense"):
    eng = ServingEngine(params, cfg, _ecfg(cache_kind=kind, chunk_size=chunk),
                        policy=policy)
    hs = [eng.submit(p, sp) for p in prompts]
    eng.run()
    return [h.tokens for h in hs]


def test_seeded_sampling_invariant_to_chunk_width_and_policy():
    """The PRNG key for token i is fold_in(seed, i) — a pure function of the
    stream position — so the sampled tokens cannot depend on how the
    scheduler packed the slabs."""
    cfg, params = _params("llama2-7b")
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9], [9, 8, 7]]
    sp = SamplingParams(temperature=0.9, top_k=12, top_p=0.9, seed=42,
                        max_tokens=6)
    a = _sampled_run(params, cfg, prompts, sp, chunk=1)
    assert all(0 <= t < cfg.vocab for toks in a for t in toks)
    assert _sampled_run(params, cfg, prompts, sp, chunk=CHUNK) == a
    assert _sampled_run(params, cfg, prompts, sp, chunk=8,
                        policy=TokenBudgetPolicy(6)) == a
    assert _sampled_run(params, cfg, prompts, sp, chunk=CHUNK,
                        kind="paged") == a


def test_seeded_sampling_invariant_to_quant_backend():
    """Same seed over the same quantized weights: the xla_decode and
    reference matmul backends must emit the same sampled stream (the gumbel
    draw is a pure function of (seed, index); backend logits agree to well
    inside the sampling noise floor)."""
    from repro.core.glvq import GLVQConfig
    from repro.core.quantized import quantize_param_tree
    cfg, params = _params("llama2-7b", seed=1)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    prompts = [[1, 2, 3, 4, 5]]
    sp = SamplingParams(temperature=0.8, top_k=16, seed=3, max_tokens=4)

    def run(backend):
        eng = ServingEngine(qparams, cfg,
                            _ecfg(s_cache=16, qmeta=qmeta, backend=backend,
                                  chunk_size=4))
        hs = [eng.submit(p, sp) for p in prompts]
        eng.run()
        return [h.tokens for h in hs]

    assert run("xla_decode") == run("reference")


def test_different_seeds_give_different_streams():
    cfg, params = _params("llama2-7b")
    prompt = [[1, 2, 3]]
    mk = lambda seed: SamplingParams(temperature=2.0, seed=seed,
                                     max_tokens=12)
    a = _sampled_run(params, cfg, prompt, mk(0), chunk=1)
    b = _sampled_run(params, cfg, prompt, mk(1), chunk=1)
    assert a != b


def test_empty_prompt_rejected_at_submit():
    """No prompt -> nothing to condition decode on; must fail clearly at
    submit, not with an IndexError inside the step loop."""
    cfg, params = _params("llama2-7b")
    eng = ServingEngine(params, cfg, _ecfg())
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    cb = ContinuousBatcher(params, cfg, _ecfg())
    with pytest.raises(ValueError, match="empty prompt"):
        cb.submit(Request(rid=0, prompt=[], max_new=2))


def test_legacy_greedy_false_decorrelates_concurrent_requests():
    """greedy=False must NOT pin every request to one shared seed: two
    concurrent requests with the same prompt should draw different
    streams (per-rid default seeds), not token-identical 'random' ones."""
    cfg, params = _params("llama2-7b")
    with pytest.warns(DeprecationWarning):
        cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32,
                               dtype=jnp.float32, greedy=False)
    for rid in (0, 1):
        cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=10))
    done = cb.run()
    assert done[0].tokens != done[1].tokens


def test_legacy_greedy_false_regression():
    """PR-4's ``greedy=False`` crashed outright (``int(None[i])``); it now
    means "actually sample" and must produce valid tokens."""
    cfg, params = _params("llama2-7b")
    with pytest.warns(DeprecationWarning):
        cb = ContinuousBatcher(params, cfg, slots=2, s_cache=16,
                               dtype=jnp.float32, greedy=False)
    cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
    done = cb.run()
    assert len(done[0].tokens) == 4
    assert all(0 <= t < cfg.vocab for t in done[0].tokens)
    assert not cb.greedy


# ---------------------------------------------------------------------------
# back-compat: the PR-4 loose-kwarg call sites
# ---------------------------------------------------------------------------

def test_pr4_loose_kwargs_warn_and_match_engine_config():
    cfg, params = _params("llama2-7b", seed=1)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10]]

    def run_legacy():
        with pytest.warns(DeprecationWarning):
            cb = ContinuousBatcher(params, cfg, slots=2, s_cache=S_CACHE,
                                   dtype=jnp.float32, cache_kind="paged_q8",
                                   block_size=BLOCK, chunk_size=4)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=3))
        return {i: r.tokens for i, r in cb.run().items()}

    def run_new():
        cb = ContinuousBatcher(params, cfg,
                               _ecfg(cache_kind="paged_q8", chunk_size=4))
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=3))
        return {i: r.tokens for i, r in cb.run().items()}

    assert run_legacy() == run_new()


def test_batcher_rejects_engine_config_plus_loose_kwargs():
    cfg, params = _params("llama2-7b")
    with pytest.raises(TypeError, match="not both"):
        ContinuousBatcher(params, cfg, _ecfg(), slots=2)
    with pytest.raises(TypeError, match="unknown"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ContinuousBatcher(params, cfg, blocksize=4)


# ---------------------------------------------------------------------------
# stop tokens + done reasons
# ---------------------------------------------------------------------------

def test_stop_token_ends_generation_with_reason():
    cfg, params = _params("llama2-7b")
    prompt = [1, 2, 3, 4, 5, 6]
    ref = _oracle_generate(params, cfg, prompt, 5)
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=4))
    req = eng.generate(prompt, SamplingParams(
        max_tokens=5, stop_token_ids=(ref[1],)))
    assert req.tokens == ref[:2]                  # stop id is kept, then done
    assert req.done_reason == "stop_token"


def test_stop_token_mid_chunk_at_prompt_end():
    """chunk=4 over a 6-token prompt: the prompt ends mid-slab on the second
    chunk (take=2 < T=4) and the FIRST generated token is the stop id — the
    request must finish right there."""
    cfg, params = _params("llama2-7b")
    prompt = [1, 2, 3, 4, 5, 6]
    first = _oracle_generate(params, cfg, prompt, 1)[0]
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=4))
    req = eng.generate(prompt, SamplingParams(max_tokens=5,
                                              stop_token_ids=(first,)))
    assert req.tokens == [first]
    assert req.done_reason == "stop_token"


def test_engine_wide_default_stop_tokens():
    cfg, params = _params("llama2-7b")
    prompt = [1, 2, 3, 4, 5, 6]
    second = _oracle_generate(params, cfg, prompt, 2)[1]
    eng = ServingEngine(params, cfg,
                        _ecfg(chunk_size=4, stop_tokens=(second,)))
    req = eng.generate(prompt, SamplingParams(max_tokens=5))
    assert len(req.tokens) == 2 and req.tokens[-1] == second
    assert req.done_reason == "stop_token"


def test_done_reasons_length_and_cache_full():
    cfg, params = _params("llama2-7b")
    eng = ServingEngine(params, cfg, _ecfg(s_cache=16, slots=1))
    by_len = eng.generate([1, 2, 3], SamplingParams(max_tokens=2))
    assert by_len.done_reason == "length" and len(by_len.tokens) == 2
    full = eng.generate(list(range(1, 11)))       # no max_tokens: run out
    assert full.done_reason == "cache_full"
    # prompt fills 10 of 16 positions; the first token costs none, the rest
    # write at pos 10..15 -> 7 generated tokens
    assert len(full.tokens) == 7


# ---------------------------------------------------------------------------
# streaming: TokenEvents + RequestHandle iteration
# ---------------------------------------------------------------------------

def test_stream_yields_every_token_in_order():
    cfg, params = _params("llama2-7b")
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=4))
    h0 = eng.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=4))
    h1 = eng.submit([6, 7], SamplingParams(max_tokens=2))
    seen = {0: [], 1: []}
    finals = {}
    for ev in eng.stream():
        assert ev.index == len(seen[ev.rid])      # contiguous per request
        seen[ev.rid].append(ev.token)
        if ev.done:
            finals[ev.rid] = ev.done_reason
    assert seen[0] == h0.tokens and len(seen[0]) == 4
    assert seen[1] == h1.tokens and len(seen[1]) == 2
    assert finals == {0: "length", 1: "length"}


def test_request_handle_is_a_token_iterator():
    cfg, params = _params("llama2-7b")
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=4))
    h0 = eng.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=4))
    h1 = eng.submit([6, 7], SamplingParams(max_tokens=3))
    streamed = list(h0)                           # drives the engine itself
    assert streamed == h0.tokens and h0.done
    # the other slot advanced on the same iterations; drain whatever is left
    eng.run()
    assert h1.done and len(h1.tokens) == 3


def test_submit_duplicate_rid_rejected_until_finished():
    cfg, params = _params("llama2-7b")
    eng = ServingEngine(params, cfg, _ecfg())
    h = eng.submit([1, 2], SamplingParams(max_tokens=2), rid=5)
    with pytest.raises(ValueError, match="in flight"):
        eng.submit([3, 4], rid=5)
    eng.run()
    # finished handles are evicted (no per-request leak in a long-running
    # engine); the held handle keeps working and the rid becomes reusable
    assert h.done and 5 not in eng.handles
    h2 = eng.submit([3, 4], SamplingParams(max_tokens=1), rid=5)
    assert h2.result().done


# ---------------------------------------------------------------------------
# policies: bounded compiled-shape family + budget + parity
# ---------------------------------------------------------------------------

class _WidthRecorder:
    """Wrap a policy to record every (T, sum-of-takes) the scheduler uses."""

    def __init__(self, inner):
        self.inner = inner
        self.plans = []

    def assign(self, slots, queue):
        return self.inner.assign(slots, queue)

    def widths(self, remaining, chunk):
        t, takes = self.inner.widths(remaining, chunk)
        self.plans.append((t, sum(takes)))
        return t, takes

    def program_widths(self, chunk):
        return self.inner.program_widths(chunk)


def _spy_compiled_widths(monkeypatch):
    """Compile-count spy (the fused-qkv spy pattern): the scheduler's jitted
    step only re-enters python tracing — and so registry.chunk_step — once
    per NEW slab shape, so the recorded widths are exactly the compiled
    program family."""
    real = registry.chunk_step
    widths = []

    def spy(params, cache, tokens, pos, lens, cfg, **kw):
        widths.append(tokens.shape[1])
        return real(params, cache, tokens, pos, lens, cfg, **kw)

    monkeypatch.setattr(registry, "chunk_step", spy)
    return widths


def _policy_workload(params, cfg, policy, chunk):
    cb = ContinuousBatcher(params, cfg, _ecfg(chunk_size=chunk),
                           policy=policy)
    rng = np.random.default_rng(3)
    for i, n in enumerate((11, 3, 7, 14, 5, 2)):
        prompt = list(map(int, rng.integers(1, cfg.vocab, n)))
        cb.submit(Request(rid=i, prompt=prompt, max_new=4))
    done = cb.run()
    return {i: r.tokens for i, r in done.items()}


def test_token_budget_policy_bounded_shapes_and_budget(monkeypatch):
    """TokenBudgetPolicy must (a) only ever compile slab widths from its
    ladder, (b) keep every iteration's valid tokens within the budget
    whenever a width > 1 fit at all, and (c) emit the same tokens as FCFS —
    packing is a performance knob, not a semantics knob."""
    cfg, params = _params("llama2-7b", seed=1)
    chunk, budget = 8, 6
    ref = _policy_workload(params, cfg, FCFSPolicy(), chunk)

    widths = _spy_compiled_widths(monkeypatch)
    rec = _WidthRecorder(TokenBudgetPolicy(budget))
    out = _policy_workload(params, cfg, rec, chunk)
    assert out == ref
    allowed = set(rec.inner.program_widths(chunk))
    assert set(widths) <= allowed                 # bounded compile family
    assert len(set(widths)) <= len(default_ladder(chunk))
    assert any(t > 1 for t, _ in rec.plans)       # it did chunk prefill
    for t, total in rec.plans:
        if t > 1:
            assert total <= budget, (t, total)


def test_fcfs_policy_compiles_exactly_two_shapes(monkeypatch):
    cfg, params = _params("llama2-7b", seed=1)
    widths = _spy_compiled_widths(monkeypatch)
    _policy_workload(params, cfg, FCFSPolicy(), 8)
    assert set(widths) == {1, 8}


def test_token_budget_solo_prefill_gets_full_width():
    """A lone prefill with an otherwise idle engine should take the widest
    rung the budget allows — that's the TTFT win over a fixed chunk."""
    pol = TokenBudgetPolicy(8)
    t, takes = pol.widths([20, None], 8)
    assert (t, takes) == (8, [8, 0])
    # a decode slot riding along halves the affordable width
    t, takes = pol.widths([20, 0], 8)
    assert t == 4 and takes == [4, 1]
    # pure decode runs at T=1 regardless
    t, takes = pol.widths([0, 0], 8)
    assert t == 1 and takes == [1, 1]


def test_token_budget_policy_validation():
    with pytest.raises(ValueError, match="token_budget"):
        TokenBudgetPolicy(0)
    with pytest.raises(ValueError, match="ladder"):
        TokenBudgetPolicy(4, ladder=(0, 2))
    assert default_ladder(8) == (1, 2, 4, 8)
    assert default_ladder(6) == (1, 2, 4, 6)
    assert default_ladder(1) == (1,)


# ---------------------------------------------------------------------------
# tensor-parallel sampled serving (8-device mesh; subprocess fallback)
# ---------------------------------------------------------------------------

_multidev = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); covered by the subprocess test on 1 device")


@_multidev
def test_tp_sampled_serving_matches_meshless():
    """Seeded in-graph sampling over TP-sharded quantized weights must emit
    the meshless stream — the sampled ids cross the host boundary, the
    [B, vocab] logits don't."""
    from repro.core.glvq import GLVQConfig
    from repro.core.quantized import quantize_param_tree
    cfg, params = _params("llama2-7b", seed=1)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10]]
    sp = SamplingParams(temperature=0.8, top_k=16, seed=11, max_tokens=3)

    def run(mesh):
        eng = ServingEngine(
            qparams, cfg,
            _ecfg(s_cache=16, qmeta=qmeta, backend="xla_decode",
                  cache_kind="paged_q8", chunk_size=4, mesh=mesh))
        hs = [eng.submit(p, sp) for p in prompts]
        eng.run()
        return [h.tokens for h in hs]

    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    assert run(mesh) == run(None)


def test_tp_sampled_forced_8dev_subprocess():
    """Under the plain tier-1 run (1 device) re-run the TP sampling test on
    a forced 8-device CPU so the sharded sampled path is always exercised."""
    if jax.device_count() >= 8:
        pytest.skip("multi-device host: the direct test above already ran")
    if os.environ.get("REPRO_SKIP_TP_SUBPROCESS"):
        pytest.skip("REPRO_SKIP_TP_SUBPROCESS set: the caller runs the "
                    "forced-8-device suite itself (scripts/ci.sh)")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "tp and not subprocess", "-p", "no:cacheprovider"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=1800)
    assert out.returncode == 0, (out.stdout[-3000:] + out.stderr[-3000:])


# ---------------------------------------------------------------------------
# per-token logprobs (in-graph gather riding the existing host boundary)
# ---------------------------------------------------------------------------

def test_token_logprobs_unit():
    from repro.serving.sampling import token_logprobs
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(4, 17)) * 3, jnp.float32)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lp, tv, ti = token_logprobs(logits, toks, n_top=0)
    want = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    np.testing.assert_allclose(np.asarray(lp),
                               want[np.arange(4), np.asarray(toks)],
                               rtol=1e-5)
    assert tv.shape == (4, 0) and ti.shape == (4, 0)
    lp3, tv3, ti3 = token_logprobs(logits, toks, n_top=3)
    np.testing.assert_allclose(np.asarray(lp3), np.asarray(lp), rtol=1e-6)
    # top-k: descending, normalized, led by the argmax token
    assert np.all(np.diff(np.asarray(tv3), axis=-1) <= 1e-7)
    np.testing.assert_array_equal(np.asarray(ti3[:, 0]), np.asarray(toks))
    np.testing.assert_allclose(np.asarray(tv3[:, 0]), np.asarray(lp),
                               rtol=1e-5)
    assert np.all(np.asarray(tv3) <= 1e-6)


def test_logprobs_model_distribution_invariant_to_temperature():
    """Reported logprobs are under the MODEL distribution (raw logits),
    so sampled-token events stay comparable across sampling params."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    streams = {}
    for temp in (0.0, 0.7):
        ecfg = EngineConfig(dtype=jnp.float32, chunk_size=2, s_cache=48,
                            slots=2, topk_logprobs=2)
        eng = ServingEngine(params, cfg, ecfg)
        sp = SamplingParams(temperature=temp, seed=0, max_tokens=4)
        eng.submit(list(range(1, 9)), sp, rid=0)
        evs = list(eng.stream())
        assert all(ev.logprob is not None for ev in evs)
        # every reported top-k value must equal log_softmax of raw logits
        # for that token -- spot-checked via the greedy run's agreement
        streams[temp] = [(ev.token, ev.logprob, ev.top_logprobs)
                         for ev in evs]
    # the greedy run's sampled token leads its own top-k
    for tok, _, top in streams[0.0]:
        assert top[0][0] == tok
    # sampling shapes the CHOICE, not the report: at temp 0.7 a sampled
    # token may be a top-k runner-up, but its logprob still matches the
    # model-distribution value reported in the top-k list
    for tok, lp, top in streams[0.7]:
        d = dict(top)
        if tok in d:
            assert abs(lp - d[tok]) < 1e-5
        assert all(v <= 1e-6 for v in d.values())


def test_logprobs_off_by_default():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg,
                        EngineConfig(dtype=jnp.float32, s_cache=48, slots=2))
    eng.submit(list(range(1, 9)), SamplingParams(max_tokens=3), rid=0)
    evs = list(eng.stream())
    assert evs and all(ev.logprob is not None for ev in evs)
    assert all(ev.top_logprobs is None for ev in evs)
