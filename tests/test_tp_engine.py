"""Tensor-parallel quantized-execution parity suite.

The acceptance bar for the TP engine: on a multi-device mesh, sharded
``qt.matmul`` (uniform bits 2/3/4 and mixed-bit SDBA, column- and
row-parallel) matches the unsharded ``reference`` backend, and each device's
addressable ``packed`` shard is ~1/TP of the full payload in word-unit-
aligned chunks.

The parametrized tests below need >= 8 devices; under the normal tier-1 run
(single CPU device) ``test_tp_parity_forced_8dev_subprocess`` re-runs this
whole file in a subprocess with ``--xla_force_host_platform_device_count=8``
so the suite is exercised either way.  ``scripts/ci.sh`` also runs the file
directly on a forced-8-device CPU.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import GLVQConfig, QuantTensor, qtensor, quantize_layer
from repro.core.quantized import (QuantLinearMeta, decode_segments,
                                  quantize_param_tree, segment_layer)
from repro.core.testing import synthetic_payload
from repro.kernels import ops
from repro.parallel import sharding

_multidev = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); covered by the subprocess test on 1 device")

K, N, M, D = 512, 320, 5, 8          # n_groups=4; M=5 exercises the M-pad path


def _mesh(tp: int):
    return jax.make_mesh((jax.device_count() // tp, tp), ("data", "model"))


def _assert_close(y, ref):
    tol = 2e-6 * float(np.abs(ref).max()) + 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=5e-4, atol=tol)


# --- uniform-bit parity ------------------------------------------------------

@_multidev
@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("parallel", ["column", "row"])
@pytest.mark.parametrize("tp", [2, 4])
def test_tp_uniform_parity(bits, parallel, tp):
    rng = np.random.default_rng(bits * 7 + tp)
    meta = QuantLinearMeta(k=K, n=N, bits=bits, d=D, group_size=128)
    payload = synthetic_payload(rng, K, N, bits, D)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    qt = QuantTensor.from_payload(payload, meta)
    ref = qt.matmul(x, backend="reference", out_dtype=jnp.float32)
    mesh = _mesh(tp)
    assert ops.tp_shardable(meta, tp, parallel)
    for backend in ("xla_decode", "pallas_fused"):
        qts = QuantTensor.from_payload(payload, meta,
                                       backend=backend).with_mesh(
                                           mesh, parallel)
        y = jax.jit(lambda x, q: q.matmul(x, out_dtype=jnp.float32))(x, qts)
        _assert_close(y, np.asarray(ref))


# --- mixed-bit (SDBA) parity -------------------------------------------------

def _mixed_layer(rng, bits_per_group):
    w = jnp.asarray(rng.standard_t(3, size=(K, N)) * 0.02, jnp.float32)
    cfg = GLVQConfig(d=D, bits=3, iters=3)
    q = quantize_layer(w, None, cfg, jnp.asarray(bits_per_group))
    return segment_layer(q, cfg)


@_multidev
@pytest.mark.parametrize("parallel,tp", [("column", 2), ("column", 4),
                                         ("row", 2)])
def test_tp_mixed_parity(parallel, tp):
    # bits chosen so every segment has 2 groups -> row-shardable at tp=2;
    # column sharding only needs N % (tp * lcm(per_word, d)) == 0
    rng = np.random.default_rng(31)
    segs = _mixed_layer(rng, [2, 4, 2, 4])
    assert len(segs.segments) == 2
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    ref = np.asarray(x @ decode_segments(segs))
    mesh = _mesh(tp)
    for m, _, _ in segs.segments:
        assert ops.tp_shardable(m, tp, parallel)
    for backend in ("xla_decode", "pallas_fused"):
        qts = QuantTensor.from_segments(segs, backend=backend).with_mesh(
            mesh, parallel)
        y = jax.jit(lambda x, q: q.matmul(x, out_dtype=jnp.float32))(x, qts)
        _assert_close(y, ref)


@_multidev
@pytest.mark.parametrize("parallel", ["column", "row"])
def test_tp_composes_with_data_sharded_batch(parallel):
    """When M divides the data axes, activations shard over them inside the
    shard_map (no all-gather): a batch placed data-sharded must come out
    bit-identical to the replicated-batch result."""
    from jax.sharding import NamedSharding
    rng = np.random.default_rng(9)
    meta = QuantLinearMeta(k=K, n=N, bits=4, d=D, group_size=128)
    payload = synthetic_payload(rng, K, N, 4, D)
    mesh = _mesh(2)                              # (data=4, model=2)
    m = 8                                        # divisible by dp=4
    x = jnp.asarray(rng.normal(size=(m, K)), jnp.float32)
    qt = QuantTensor.from_payload(payload, meta)
    ref = qt.matmul(x, backend="reference", out_dtype=jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    qts = QuantTensor.from_payload(payload, meta,
                                   backend="xla_decode").with_mesh(
                                       mesh, parallel)
    y = jax.jit(lambda x, q: q.matmul(x, out_dtype=jnp.float32))(xs, qts)
    _assert_close(y, np.asarray(ref))


@_multidev
def test_tp_unshardable_falls_back_to_replicated():
    """Row-parallel with n_groups % tp != 0 must still be correct (fallback),
    never silently wrong."""
    rng = np.random.default_rng(5)
    segs = _mixed_layer(rng, [2, 4, 4, 4])      # segments with 1 and 3 groups
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    ref = np.asarray(x @ decode_segments(segs))
    for m, _, _ in segs.segments:
        assert not ops.tp_shardable(m, 2, "row")
    qts = QuantTensor.from_segments(segs, backend="xla_decode").with_mesh(
        _mesh(2), "row")
    y = jax.jit(lambda x, q: q.matmul(x, out_dtype=jnp.float32))(x, qts)
    _assert_close(y, ref)


# --- per-device payload bytes ------------------------------------------------

@_multidev
@pytest.mark.parametrize("tp", [2, 4])
def test_tp_packed_bytes_shrink(tp):
    """Each device's addressable packed shard must be exactly 1/TP of the
    full payload, cut on word-unit boundaries."""
    rng = np.random.default_rng(tp)
    bits = 3                                     # per_word=10: the awkward one
    meta = QuantLinearMeta(k=K, n=N, bits=bits, d=D, group_size=128)
    payload = synthetic_payload(rng, K, N, bits, D)
    mesh = _mesh(tp)
    spec = sharding._payload_leaf_spec("wq", "packed",
                                       payload["packed"].shape, tp, meta)
    assert spec == P(None, "model")
    packed = jax.device_put(payload["packed"],
                            sharding.named(spec, mesh))
    full = payload["packed"].size * 4
    unit = sharding.payload_word_unit(bits, D)
    for shard in packed.addressable_shards:
        assert shard.data.nbytes == full // tp
        assert shard.data.shape[-1] % unit == 0
    # row-parallel: the K dim shards instead, in whole code groups
    spec_r = sharding._payload_leaf_spec("wo", "packed",
                                         payload["packed"].shape, tp, meta)
    assert spec_r == P("model", None)
    packed_r = jax.device_put(payload["packed"],
                              sharding.named(spec_r, mesh))
    for shard in packed_r.addressable_shards:
        assert shard.data.nbytes == full // tp
        assert shard.data.shape[0] % meta.group_size == 0


# --- model-level: decode step with a mesh ------------------------------------

@_multidev
def test_tp_model_decode_matches_unsharded():
    """registry.decode_step(mesh=...) must reproduce the meshless logits —
    shardable layers run the shard_map path, the rest fall back."""
    from repro.configs import get_config, reduced
    from repro.models import registry
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)

    def logits(mesh):
        cache = registry.cache_init(cfg, 2, 8, jnp.float32)
        lg, _ = jax.jit(lambda p, c: registry.decode_step(
            p, c, tok, pos, cfg, dtype=jnp.float32, qmeta=qmeta,
            backend="xla_decode", mesh=mesh))(qparams, cache)
        return np.asarray(lg)

    ref = logits(None)
    np.testing.assert_allclose(logits(_mesh(2)), ref, rtol=1e-4, atol=1e-4)


@_multidev
@pytest.mark.parametrize("cache_kind", ["dense", "paged_q8"])
def test_tp_continuous_batching_matches_meshless(cache_kind):
    """Sharded serving works with every cache_kind: the scheduler with a mesh
    must emit token-identical generations to the meshless batcher."""
    from repro.configs import get_config, reduced
    from repro.models import registry
    from repro.serving.scheduler import ContinuousBatcher, Request
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, qmeta = quantize_param_tree(params, cfg=qcfg)
    prompts = [[1, 2, 3], [4, 5], [6]]

    def run(mesh):
        cb = ContinuousBatcher(qparams, cfg, slots=2, s_cache=16,
                               dtype=jnp.float32, qmeta=qmeta,
                               backend="xla_decode", cache_kind=cache_kind,
                               mesh=mesh)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new=3))
        return {i: r.tokens for i, r in cb.run().items()}

    assert run(_mesh(2)) == run(None)


# --- single-device tier-1 entry point ----------------------------------------

def test_tp_parity_forced_8dev_subprocess():
    """Under the plain tier-1 run (1 device) re-run this file on a forced
    8-device CPU so the TP path is always exercised."""
    if jax.device_count() >= 8:
        pytest.skip("multi-device host: the direct tests above already ran")
    if os.environ.get("REPRO_SKIP_TP_SUBPROCESS"):
        pytest.skip("REPRO_SKIP_TP_SUBPROCESS set: the caller runs the "
                    "forced-8-device suite itself (scripts/ci.sh)")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "not subprocess", "-p", "no:cacheprovider"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=1800)
    assert out.returncode == 0, (out.stdout[-3000:] + out.stderr[-3000:])
