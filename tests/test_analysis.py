"""Tests for repro.analysis: the static lint suite (rules R1-R8, the
allowlist/baseline machinery, the CLI contract) and the runtime sanitizer
(EngineConfig.debug_checks): clean runs stay event-free on every
cache_kind; injected corruption — bad block-table ids, cross-slot block
aliasing, NaN params — trips the matching check and counts it on the
metrics registry."""
import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint, runtime
from repro.analysis.__main__ import main as lint_main
from repro.analysis.lint import (Finding, Rule, all_rules, apply_allowlist,
                                 apply_baseline, get_rule, lint_source,
                                 load_baseline, write_baseline)
from repro.analysis.runtime import DebugCheckError, RecompileMonitor
from repro.configs import get_config, reduced
from repro.core.quantized import QuantLinearMeta
from repro.models import registry
from repro.serving.engine import EngineConfig
from repro.serving.kvcache import CACHE_KINDS
from repro.serving.scheduler import ContinuousBatcher, Request

ARCH = "llama2-7b"
S_CACHE, BLOCK, CHUNK = 32, 4, 5


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config(ARCH))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ecfg(**kw):
    kw.setdefault("dtype", jnp.float32)
    kw.setdefault("s_cache", S_CACHE)
    kw.setdefault("block_size", BLOCK)
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("slots", 2)
    kw.setdefault("debug_checks", True)
    return EngineConfig(**kw)


def _run(model, kind, corrupt=None, **eng_kw):
    cfg, params = model
    cb = ContinuousBatcher(params, cfg, _ecfg(cache_kind=kind, **eng_kw))
    cb.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7], max_new=4))
    cb.submit(Request(rid=1, prompt=[2, 3, 4], max_new=4))
    cb.step()                         # everything live before corruption
    if corrupt is not None:
        corrupt(cb)
    cb.run(max_steps=60)
    return cb


# ===========================================================================
# rule fixtures: each rule must flag its seeded violation AND pass a clean
# twin of the same shape
# ===========================================================================

# (rule, rel path, bad source, expected symbols, clean source)
RULE_FIXTURES = [
    ("R1", "launch/foo.py",
     "import time\nprint('hi')\nt0 = time.time()\n",
     {"print", "time.time"},
     "from repro.serving.metrics import Timer, log_event\n"
     "log_event('hi')\n"
     "with Timer() as t0:\n    pass\n"),
    ("R2", "serving/foo.py",
     "import numpy as np\n"
     "def drain(out):\n"
     "    return np.asarray(out), out.item(), out.tolist()\n",
     {"np.asarray", ".item", ".tolist"},
     "import numpy as np\n"
     "def drain(out):\n"
     "    return out\n"),
    ("R2", "kernels/foo.py",
     "import jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    return float(x) + 1\n",
     {"host-float"},
     "import jax.numpy as jnp\nimport jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    return x.astype(jnp.float32) + 1\n"),
    ("R3", "serving/foo.py",
     "import jax\n"
     "class C:\n"
     "    def build(self):\n"
     "        def step(x):\n"
     "            self.counter += 1\n"
     "            return x\n"
     "        self.f = jax.jit(step)\n",
     {"mutable-closure"},
     "import jax\n"
     "class C:\n"
     "    def build(self):\n"
     "        def step(x):\n"
     "            return x * 2\n"
     "        self.f = jax.jit(step)\n"),
    ("R3", "models/foo.py",
     "import jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    if x > 0:\n"
     "        return x\n"
     "    return -x\n",
     {"traced-branch"},
     # branching on .shape is static and sanctioned
     "import jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    if x.shape[0] > 4:\n"
     "        return x\n"
     "    return -x\n"),
    ("R3", "models/foo.py",
     "import jax\n"
     "def build(fns):\n"
     "    for fn in fns:\n"
     "        fn = jax.jit(fn)\n",
     {"jit-in-loop"},
     "import jax\n"
     "def build(fns):\n"
     "    return [jax.jit(f) for f in fns]\n"
     "fns2 = build([])\n"),
    ("R3", "kernels/foo.py",
     "import jax, functools\n"
     "@functools.partial(jax.jit, static_argnames=('opts',))\n"
     "def f(x, opts=[1]):\n"
     "    return x\n",
     {"nonhashable-static"},
     "import jax, functools\n"
     "@functools.partial(jax.jit, static_argnames=('opts',))\n"
     "def f(x, opts=(1,)):\n"
     "    return x\n"),
    ("R4", "kernels/foo.py",
     "import jax.experimental.pallas as pl\n"
     "def run(x, kern):\n"
     "    return pl.pallas_call(\n"
     "        kern,\n"
     "        grid=(4, 4),\n"
     "        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],\n"
     "        out_specs=pl.BlockSpec((8, 144), lambda i, j: (i, j)),\n"
     "    )(x)\n",
     {"index-map-arity", "tile-shape"},
     "import jax.experimental.pallas as pl\n"
     "def run(x, kern):\n"
     "    return pl.pallas_call(\n"
     "        kern,\n"
     "        grid=(4, 4),\n"
     "        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0))],\n"
     "        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),\n"
     "    )(x)\n"),
    ("R4", "kernels/foo.py",
     "from jax.experimental.pallas import tpu as pltpu\n"
     "import jax.experimental.pallas as pl\n"
     "def spec(nb):\n"
     "    return pltpu.PrefetchScalarGridSpec(\n"
     "        num_scalar_prefetch=2,\n"
     "        grid=(2, 3),\n"
     "        in_specs=[pl.BlockSpec((1, 8, 128),\n"
     "                               lambda i, j, tbl: (i, j, 0))],\n"
     "        out_specs=pl.BlockSpec((1, 8, 128),\n"
     "                              lambda i, j, tbl, ps: (i, j, 0)),\n"
     "        scratch_shapes=[pltpu.VMEM((0,), None)],\n"
     "    )\n",
     {"index-map-arity", "scratch-shape"},
     "from jax.experimental.pallas import tpu as pltpu\n"
     "import jax.experimental.pallas as pl\n"
     "def spec(nb):\n"
     "    return pltpu.PrefetchScalarGridSpec(\n"
     "        num_scalar_prefetch=2,\n"
     "        grid=(2, 3),\n"
     "        in_specs=[pl.BlockSpec((1, 8, 128),\n"
     "                               lambda i, j, tbl, ps: (i, j, 0))],\n"
     "        out_specs=pl.BlockSpec((1, 8, 128),\n"
     "                              lambda i, j, tbl, ps: (i, j, 0)),\n"
     "        scratch_shapes=[pltpu.VMEM((8, 128), None)],\n"
     "    )\n"),
    ("R5", "parallel/foo.py",
     "from jax.sharding import PartitionSpec as P\n"
     "spec = P('tensor', None)\n",
     {"unknown-axis"},
     "from jax.sharding import PartitionSpec as P\n"
     "spec = P('model', None)\n"),
    ("R6", "kernels/foo.py",
     "import numpy as np\nimport jax.numpy as jnp\n"
     "a = np.zeros(4, dtype=np.float64)\n"
     "b = jnp.zeros(4, dtype=float)\n"
     "c = a.astype('float64')\n",
     {"float64"},
     "import numpy as np\nimport jax.numpy as jnp\n"
     "a = np.zeros(4, dtype=np.float32)\n"
     "b = jnp.zeros(4, dtype=jnp.float32)\n"
     "c = a.astype(np.float32)\n"),
    ("R7", "serving/foo.py",
     "from repro.serving.engine import EngineConfig\n"
     "def tune(ecfg: EngineConfig):\n"
     "    ecfg.slots = 8\n"
     "    object.__setattr__(ecfg, 'chunk_size', 4)\n"
     "    setattr(ecfg, 'block_size', 32)\n",
     {"config-mutation", "object.__setattr__"},
     "from repro.serving.engine import EngineConfig\n"
     "def tune(ecfg: EngineConfig):\n"
     "    return ecfg.replace(slots=8, chunk_size=4, block_size=32)\n"),
    ("R8", "serving/foo.py",
     "import numpy as np\nimport random\n"
     "seed = np.random.default_rng(0).integers(9)\n"
     "jitter = random.random()\n",
     {"np.random", "random"},
     "import jax\n"
     "key = jax.random.PRNGKey(0)\n"
     "jitter = jax.random.uniform(key)\n"),
]


def test_rule_registry_complete():
    names = [r.name for r in all_rules()]
    assert names == [f"R{i}" for i in range(1, 9)]


@pytest.mark.parametrize(
    "rule_name,rel,bad,symbols,clean",
    RULE_FIXTURES,
    ids=[f"{r}-{'-'.join(sorted(s))[:40]}" for r, _, _, s, _ in RULE_FIXTURES])
def test_rule_flags_seeded_violation(rule_name, rel, bad, symbols, clean):
    rule = get_rule(rule_name)
    found = lint_source(rule, rel, bad, allowlist=False)
    assert symbols <= {f.symbol for f in found}, \
        f"{rule_name} missed its seeded violation: {found}"
    assert lint_source(rule, rel, clean, allowlist=False) == [], \
        f"{rule_name} false-positived on the clean twin"


def test_rule_scope_and_exclude():
    r2 = get_rule("R2")
    # out of scope (not serving/ or kernels/): same source, no findings
    bad = "import numpy as np\nx = np.asarray(object())\n"
    assert lint_source(r2, "serving/x.py", bad, allowlist=False)
    assert lint_source(r2, "launch/x.py", bad, allowlist=False) == []
    r1 = get_rule("R1")
    assert lint_source(r1, "serving/metrics.py", "print('x')\n") == []


def test_allowlist_pinned_counts():
    class Toy(Rule):
        name = "T0"
        allow = {("pkg/a.py", "print"): (2, "two sanctioned prints")}

    def mk(n):
        return [Finding("T0", "pkg/a.py", i, "print", "bare print")
                for i in range(n)]

    assert apply_allowlist(Toy(), mk(2)) == []          # at the pin
    over = apply_allowlist(Toy(), mk(3))                # growth fails
    assert len(over) == 3
    assert "2 allowed" in over[0].message
    # a different symbol in the same file is NOT covered
    other = [Finding("T0", "pkg/a.py", 1, "time.time", "m")]
    assert apply_allowlist(Toy(), other) == other


def test_baseline_roundtrip(tmp_path):
    findings = [Finding("R1", "a.py", 3, "print", "m"),
                Finding("R1", "a.py", 9, "print", "m"),
                Finding("R6", "b.py", 1, "float64", "m")]
    path = tmp_path / "baseline.txt"
    write_baseline(findings, path)
    base = load_baseline(path)
    assert base == Counter({"R1|a.py|print": 2, "R6|b.py|float64": 1})
    fresh, stale = apply_baseline(findings, base)
    assert fresh == [] and not stale
    # one fixed -> stale debt reported, none fresh
    fresh, stale = apply_baseline(findings[:2], base)
    assert fresh == [] and stale == Counter({"R6|b.py|float64": 1})
    # one NEW finding -> exactly it escapes the baseline
    extra = findings + [Finding("R1", "c.py", 2, "print", "m")]
    fresh, stale = apply_baseline(extra, base)
    assert [f.path for f in fresh] == ["c.py"] and not stale


def test_cli_contract(tmp_path, capsys):
    # seeded violation -> exit 1; baselined -> exit 0; clean file -> exit 0
    bad = tmp_path / "serving"
    bad.mkdir()
    f = bad / "hot.py"
    f.write_text("import numpy as np\nx = np.asarray(object()).item()\n")
    base = tmp_path / "baseline.txt"
    assert lint_main([str(tmp_path), "--baseline", str(base)]) == 1
    assert lint_main([str(tmp_path), "--baseline", str(base),
                      "--write-baseline"]) == 0
    assert lint_main([str(tmp_path), "--baseline", str(base)]) == 0
    f.write_text("import numpy as np\n")
    out = lint_main([str(tmp_path), "--baseline", str(base)])
    assert out == 0          # stale baseline entries warn, never fail
    assert "no longer matches" in capsys.readouterr().out
    assert lint_main(["--rules", "R1,nope"]) == 2
    assert lint_main([str(tmp_path / "missing.py")]) == 2


def test_repo_is_lint_clean_with_empty_baseline():
    """The shipped contract: src/repro passes every rule with the (empty)
    checked-in baseline — AST rules here; R5's config-loading project
    check runs in ci.sh where the import cost is already paid."""
    src = lint.repo_root() / "src" / "repro"
    rules = all_rules()
    findings = lint.lint_paths([src], rules, project_checks=False)
    assert findings == [], "\n".join(f.format() for f in findings)
    baseline = load_baseline(lint.repo_root() / "src" / "repro" /
                             "analysis" / "baseline.txt")
    assert not baseline, "baseline must ship empty (see ISSUE 8)"


# ===========================================================================
# runtime sanitizer (EngineConfig.debug_checks)
# ===========================================================================

@pytest.mark.parametrize("kind", CACHE_KINDS)
def test_debug_clean_run_is_event_free(model, kind):
    cb = _run(model, kind)
    assert sorted(cb.finished) == [0, 1]
    snap = cb.metrics.snapshot()
    assert runtime.FAILURE_COUNTER not in snap.get("counters", {})


def test_debug_off_is_graph_free(model):
    cfg, params = model
    cb = ContinuousBatcher(params, cfg, _ecfg(cache_kind="paged",
                                              debug_checks=False))
    assert cb._debug is False and not hasattr(cb, "_checked_step")
    # the jitted step is the raw closure: no checkify primitives traced in
    b = len(cb.slots)
    toks = jnp.zeros((b, 1), jnp.int32)
    vec_i = jnp.zeros((b,), jnp.int32)
    vec_f = jnp.zeros((b,), jnp.float32)
    jaxpr = jax.make_jaxpr(cb._step_fn)(
        cb.params, cb.cache, toks, vec_i, vec_i, vec_i, vec_i,
        vec_f, vec_i, jnp.ones((b,), jnp.float32))
    prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert not any("check" in p for p in prims), prims


def test_debug_catches_corrupt_block_table(model):
    def corrupt(cb):
        tbl = np.array(cb.cache["table"])
        tbl[0, 0] = 10_000                      # out of [0, num_blocks)
        cb.cache["table"] = jnp.asarray(tbl)

    with pytest.raises(DebugCheckError) as ei:
        _run(model, "paged", corrupt)
    assert ei.value.check == "block_table"


def test_debug_catches_injected_nan(model):
    def corrupt(cb):
        leaves, td = jax.tree_util.tree_flatten(cb.params)
        big = max(range(len(leaves)),
                  key=lambda i: getattr(leaves[i], "size", 0))
        leaves[big] = jnp.full_like(leaves[big], jnp.nan)
        cb.params = jax.tree_util.tree_unflatten(td, leaves)

    with pytest.raises(DebugCheckError) as ei:
        _run(model, "dense", corrupt)
    assert ei.value.check == "nan_logits"


def test_debug_catches_block_aliasing(model):
    def corrupt(cb):
        assert int(cb.pages.counts[0]) and int(cb.pages.counts[1])
        cb.pages.table[1, 0] = cb.pages.table[0, 0]

    with pytest.raises(DebugCheckError) as ei:
        _run(model, "paged_q8", corrupt)
    assert ei.value.check == "block_aliasing"


def test_debug_trip_counts_on_metrics(model):
    def corrupt(cb):
        tbl = np.array(cb.cache["table"])
        tbl[0, 0] = -3
        cb.cache["table"] = jnp.asarray(tbl)

    cfg, params = model
    cb = ContinuousBatcher(params, cfg, _ecfg(cache_kind="paged"))
    cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    cb.step()
    corrupt(cb)
    with pytest.raises(DebugCheckError):
        cb.run(max_steps=10)
    counters = cb.metrics.snapshot()["counters"]
    assert counters[runtime.FAILURE_COUNTER] == {"check=block_table": 1.0}


def test_aliasing_checker_accepts_clean_and_rejects_freed(model):
    cfg, params = model
    cb = ContinuousBatcher(params, cfg, _ecfg(cache_kind="paged"))
    cb.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=2))
    cb.step()
    assert runtime.check_block_aliasing(cb.pages) > 0
    # a live block that is ALSO on the free list must be rejected
    live = int(cb.pages.table[0, 0])
    cb.pages.alloc._free_set.add(live)
    with pytest.raises(DebugCheckError) as ei:
        runtime.check_block_aliasing(cb.pages)
    assert ei.value.check == "block_aliasing"


def test_debug_catches_corrupted_refcount(model):
    """Owner count != refcount (a skipped incref/decref) must trip
    check=block_aliasing and count on the failure counter."""
    def corrupt(cb):
        live = int(cb.pages.table[0, 0])
        cb.pages.alloc._refs[live] += 1        # phantom owner

    with pytest.raises(DebugCheckError) as ei:
        _run(model, "paged", corrupt)
    assert ei.value.check == "block_aliasing"


def test_refcount_zero_live_block_trips(model):
    """A block referenced by a slot table while at refcount 0 (as if it
    had been parked/evicted under a live reader) must be rejected."""
    cfg, params = model
    cb = ContinuousBatcher(params, cfg, _ecfg(cache_kind="paged",
                                              prefix_cache=True))
    cb.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=2))
    cb.step()
    live = int(cb.pages.table[0, 0])
    del cb.pages.alloc._refs[live]
    with pytest.raises(DebugCheckError) as ei:
        runtime.check_block_aliasing(cb.pages)
    assert ei.value.check == "block_aliasing"
    with pytest.raises(DebugCheckError):
        cb.run(max_steps=10)
    snap = cb.metrics.snapshot()["counters"]
    assert snap[runtime.FAILURE_COUNTER]["check=block_aliasing"] == 1.0


def test_recompile_monitor():
    mon = RecompileMonitor(3)
    mon.observe(compiles=3, iterations=10)        # at budget: fine
    with pytest.raises(DebugCheckError) as ei:
        mon.observe(compiles=4, iterations=11)
    assert ei.value.check == "recompile_storm"


def test_payload_alignment_check():
    meta = QuantLinearMeta(k=32, n=16, bits=4, d=8, group_size=32)
    good = {"layer": {"attn": {"wq": dict(
        packed=jnp.zeros((32, meta.n_words), jnp.uint32),
        g=jnp.zeros((1, 8, 8)), mu=jnp.zeros((1,)),
        scale=jnp.zeros((1,)))}}}
    qmeta = {("attn", "wq"): meta}
    assert runtime.check_payload_alignment(good, qmeta) == 1
    bad = jax.tree_util.tree_map(lambda x: x, good)
    bad["layer"]["attn"]["wq"]["packed"] = \
        jnp.zeros((32, meta.n_words + 1), jnp.uint32)
    with pytest.raises(DebugCheckError) as ei:
        runtime.check_payload_alignment(bad, qmeta)
    assert ei.value.check == "payload_alignment"
    assert runtime.check_payload_alignment(good, None) == 0


def test_debug_checks_with_quantized_payloads(model):
    """debug_checks composes with the QuantTensor engine: the payload
    alignment check passes at build and a clean quantized run finishes."""
    cfg, params = model
    from repro.core.glvq import GLVQConfig
    from repro.core import quantized
    qparams, qmeta = quantized.quantize_param_tree(
        params, cfg=GLVQConfig(d=8, bits=4, iters=2, group_size=32))
    cb = ContinuousBatcher(qparams, cfg,
                           _ecfg(cache_kind="paged", qmeta=qmeta))
    cb.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new=3))
    cb.run(max_steps=30)
    assert sorted(cb.finished) == [0]


def test_parse_failure_tag():
    check, msg = runtime.parse_failure("[debug:bounds] pos escaped")
    assert (check, msg) == ("bounds", "pos escaped")
    assert runtime.parse_failure("something else")[0] == "unknown"
