"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype/bits sweep."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import packing
from repro.kernels import ops, ref


def _payload(rng, k, n, bits, d):
    n_g = k // 128
    lo = -(2 ** (bits - 1)) if bits > 1 else -1
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 0
    codes = rng.integers(lo, hi + 1, size=(k, n))
    packed = packing.pack_codes(jnp.asarray(codes, jnp.int32), bits)
    g = jnp.asarray(rng.normal(size=(n_g, d, d)) * 0.1 + np.eye(d) * 0.3,
                    jnp.float32)
    mu = jnp.asarray(rng.uniform(10, 250, size=(n_g,)), jnp.float32)
    scale = jnp.asarray(rng.uniform(0.3, 3.0, size=(n_g,)), jnp.float32)
    return packed, g, mu, scale


@pytest.mark.parametrize("bits,d", [(1, 8), (2, 8), (3, 8), (4, 8),
                                    (2, 16), (4, 16), (2, 32), (8, 16)])
def test_glvq_matmul_matches_ref(bits, d):
    rng = np.random.default_rng(bits * 100 + d)
    k, n, m = 256, 640, 24
    packed, g, mu, scale = _payload(rng, k, n, bits, d)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    y_ref = ref.glvq_matmul_ref(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    y_ker = ops.glvq_matmul(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    assert y_ker.shape == (m, n)
    # mu-law expand is exponential: f32 reduction-order noise in the decode
    # matmul is amplified, so tolerance must scale with the output magnitude.
    tol = 2e-6 * float(np.abs(np.asarray(y_ref)).max()) + 1e-5
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-4, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_glvq_matmul_dtypes(dtype):
    rng = np.random.default_rng(11)
    k, n, m, bits, d = 128, 320, 8, 4, 8
    packed, g, mu, scale = _payload(rng, k, n, bits, d)
    x = jnp.asarray(rng.normal(size=(m, k))).astype(dtype)
    y_ref = ref.glvq_matmul_ref(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    y_ker = ops.glvq_matmul(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    np.testing.assert_allclose(np.asarray(y_ker, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-1)


def test_glvq_matmul_irregular_m():
    rng = np.random.default_rng(12)
    k, n, bits, d = 128, 160, 2, 8
    packed, g, mu, scale = _payload(rng, k, n, bits, d)
    for m in (1, 4, 5, 13):
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        y_ref = ref.glvq_matmul_ref(x, packed, g, mu, scale, bits=bits, d=d, n=n)
        y_ker = ops.glvq_matmul(x, packed, g, mu, scale, bits=bits, d=d, n=n)
        tol = 2e-6 * float(np.abs(np.asarray(y_ref)).max()) + 1e-5
        np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                                   rtol=2e-4, atol=tol)


def test_glvq_matmul_pads_m_instead_of_degrading(monkeypatch):
    """M not a multiple of 8 (a 4-slot decode batch) must pad M up and keep
    an MXU-sized m_block >= 8, not fall back to m_block=1 row-at-a-time."""
    rng = np.random.default_rng(13)
    k, n, bits, d = 128, 160, 2, 8
    packed, g, mu, scale = _payload(rng, k, n, bits, d)
    calls = {}
    real = ops.glvq_matmul_pallas

    def spy(x, *args, **kw):
        calls["m_block"] = kw["m_block"]
        calls["m_padded"] = x.shape[0]
        return real(x, *args, **kw)

    monkeypatch.setattr(ops, "glvq_matmul_pallas", spy)
    for m, want_pad in ((4, 8), (13, 16), (8, 8)):
        calls.clear()
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        # bypass the jit wrapper so the spy observes the traced call
        y = ops.glvq_matmul.__wrapped__(x, packed, g, mu, scale, bits=bits,
                                        d=d, n=n, interpret=True)
        assert calls["m_block"] >= 8
        assert calls["m_padded"] == want_pad, (m, calls)
        assert y.shape == (m, n)
        y_ref = ref.glvq_matmul_ref(x, packed, g, mu, scale, bits=bits,
                                    d=d, n=n)
        tol = 2e-6 * float(np.abs(np.asarray(y_ref)).max()) + 1e-5
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=tol)


@pytest.mark.parametrize("bits,d", [(2, 8), (3, 8), (4, 16), (2, 32), (5, 8)])
def test_babai_quantize_matches_ref(bits, d):
    rng = np.random.default_rng(bits * 10 + d)
    k, n = 256, 512
    n_g = k // 128
    w = jnp.asarray(rng.standard_t(3, size=(k, n)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n_g, d, d)) * 0.05 + np.eye(d) * 0.4,
                    jnp.float32)
    ginv = jnp.linalg.inv(g)
    mu = jnp.asarray(rng.uniform(10, 250, size=(n_g,)), jnp.float32)
    scale = jnp.max(jnp.abs(w.reshape(n_g, -1)), axis=1)
    z_ref = ref.babai_quantize_ref(w, ginv, mu, scale, bits=bits, d=d)
    z_ker = ops.babai_quantize(w, ginv, mu, scale, bits=bits, d=d)
    mismatch = int(jnp.sum(z_ref != z_ker))
    # rounding ties at .5 boundaries may flip; require < 0.01% disagreement
    assert mismatch <= max(1, z_ref.size // 10_000)


def test_kernel_quantize_then_matmul_consistency():
    """End to end: kernel-quantized codes -> kernel matmul == oracle chain."""
    rng = np.random.default_rng(13)
    k, n, m, bits, d = 128, 320, 4, 3, 8
    n_g = k // 128
    w = jnp.asarray(rng.standard_t(3, size=(k, n)) * 0.05, jnp.float32)
    g = jnp.asarray(np.eye(d)[None] * 0.2, jnp.float32)
    ginv = jnp.linalg.inv(g)
    mu = jnp.asarray([60.0], jnp.float32)
    scale = jnp.max(jnp.abs(w))[None]
    z = ops.babai_quantize(w, ginv, mu, scale, bits=bits, d=d)
    packed = packing.pack_codes(z, bits)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    y_ker = ops.glvq_matmul(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    y_ref = ref.glvq_matmul_ref(x, packed, g, mu, scale, bits=bits, d=d, n=n)
    tol = 2e-6 * float(np.abs(np.asarray(y_ref)).max()) + 1e-5
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-4, atol=tol)
