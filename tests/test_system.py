"""End-to-end behaviour: train a tiny LM, calibrate, quantize, evaluate.

This is the repo's miniature of the paper's full pipeline (Tables 1-3):
pretrained model -> calibration H -> GLVQ / baselines -> perplexity deltas.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.glvq import GLVQConfig
from repro.data.calibration import collect_h, quantize_model
from repro.data.synthetic import make_batch, markov_tokens, token_batches
from repro.launch.train import make_train_step, opt_init
from repro.models import registry
from repro.optim import AdamWConfig


@pytest.fixture(scope="module")
def trained_tiny_lm():
    cfg = reduced(get_config("llama2-7b"))
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False,
                                   dtype=jnp.float32))
    losses = []
    for batch in token_batches(cfg, 8, 32, 60, seed=0):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    return cfg, params, losses


def _ppl(params, cfg, seed=99, n=4):
    tot, cnt = 0.0, 0
    for i in range(n):
        batch = make_batch(cfg, 8, 32, seed + i,
                           stream=markov_tokens(cfg.vocab, 40_000, 0))
        loss = registry.loss_fn(params, batch, cfg, dtype=jnp.float32,
                                remat=False)
        tot += float(loss)
        cnt += 1
    return float(np.exp(tot / cnt))


def test_training_reduces_loss(trained_tiny_lm):
    _, _, losses = trained_tiny_lm
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_full_ptq_pipeline_quality_ordering(trained_tiny_lm):
    """GLVQ ppl <= RTN ppl at 3 bits; 4-bit <= 2-bit; all finite."""
    cfg, params, _ = trained_tiny_lm
    calib = [make_batch(cfg, 4, 32, 1000 + i,
                        stream=markov_tokens(cfg.vocab, 40_000, 0))
             for i in range(2)]
    h_acc = collect_h(params, calib, cfg)
    base_ppl = _ppl(params, cfg)
    qcfg = GLVQConfig(d=8, bits=3, iters=100, group_size=32)

    glvq3, _ = quantize_model(params, cfg, method="glvq", qcfg=qcfg,
                              h_acc=h_acc)
    rtn3, _ = quantize_model(params, cfg, method="rtn", qcfg=qcfg)
    glvq3_ppl = _ppl(glvq3, cfg)
    rtn3_ppl = _ppl(rtn3, cfg)
    assert np.isfinite(glvq3_ppl) and np.isfinite(rtn3_ppl)
    # On this 64-dim near-Gaussian tiny model RTN's per-column scales are
    # already near-optimal; GLVQ must stay within noise of it (the paper's
    # decisive wins appear on heavy-tailed full-scale weights — see the
    # synthetic-weight tests in test_core.py and EXPERIMENTS.md).
    assert glvq3_ppl <= rtn3_ppl * 1.05, (glvq3_ppl, rtn3_ppl, base_ppl)
    # the paper's core mechanism claim: learned group lattices crush a fixed
    # shared lattice (Table 7)
    fixed3, _ = quantize_model(params, cfg, method="fixed-lattice", qcfg=qcfg,
                               h_acc=h_acc)
    assert glvq3_ppl < _ppl(fixed3, cfg) * 0.85

    q2, _ = quantize_model(params, cfg, method="glvq",
                           qcfg=dataclasses.replace(qcfg, bits=2), h_acc=h_acc)
    q4, _ = quantize_model(params, cfg, method="glvq",
                           qcfg=dataclasses.replace(qcfg, bits=4), h_acc=h_acc)
    assert _ppl(q4, cfg) <= _ppl(q2, cfg) * 1.02
    # 4-bit should be near-lossless on this scale
    assert _ppl(q4, cfg) <= base_ppl * 1.35


def test_fractional_rate_between_integer_neighbours(trained_tiny_lm):
    cfg, params, _ = trained_tiny_lm
    qcfg = GLVQConfig(d=8, bits=2, iters=60, group_size=32)
    q15, rep = quantize_model(params, cfg, method="glvq", qcfg=qcfg, bits=1.5)
    assert rep.bits == 1.5
    p15 = _ppl(q15, cfg)
    p1 = _ppl(quantize_model(params, cfg, method="glvq", qcfg=qcfg, bits=1.0)[0], cfg)
    p2 = _ppl(quantize_model(params, cfg, method="glvq", qcfg=qcfg, bits=2.0)[0], cfg)
    assert p2 <= p15 * 1.05 and p15 <= p1 * 1.05, (p1, p15, p2)


def test_quantized_serving_matches_fake_quant(trained_tiny_lm):
    """Packed streaming decode == fake-quant dense decode (same codes)."""
    from repro.core.quantized import quantize_param_tree, materialize_tree
    cfg, params, _ = trained_tiny_lm
    qcfg = GLVQConfig(d=8, bits=4, iters=8, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)
    dense = materialize_tree(qparams, meta, jnp.float32)
    cache_q = registry.cache_init(cfg, 2, 8, jnp.float32)
    cache_d = registry.cache_init(cfg, 2, 8, jnp.float32)
    tok = jnp.asarray([1, 2], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    lq, _ = registry.decode_step(qparams, cache_q, tok, pos, cfg,
                                 dtype=jnp.float32, qmeta=meta)
    ld, _ = registry.decode_step(dense, cache_d, tok, pos, cfg,
                                 dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(ld),
                               rtol=1e-4, atol=1e-4)
