"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.data.synthetic import make_batch
from repro.models import registry
from repro.optim import AdamWConfig
from repro.launch.train import make_train_step, opt_init

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16, seed=0):
    return make_batch(cfg, b, s, seed)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes right, no NaNs."""
    cfg = reduced(get_config(arch))
    params = registry.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits = registry.forward(params, batch, cfg, dtype=jnp.float32)
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=10),
                           remat=True, dtype=jnp.float32)
    opt = opt_init(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))), params, p2))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = registry.init_params(KEY, cfg)
    cache = registry.cache_init(cfg, 2, 16, jnp.float32)
    logits, cache2 = registry.decode_step(
        params, cache, jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32),
        cfg, dtype=jnp.float32)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama2-7b", "qwen3-1.7b", "nemotron-4-15b",
                                  "mamba2-1.3b", "recurrentgemma-9b",
                                  "minicpm-2b", "qwen2-vl-7b"])
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode must reproduce the full causal forward exactly."""
    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.family == "vlm":
        batch = dict(tokens=toks)
    else:
        batch = dict(tokens=toks)
    full = registry.forward(params, batch, cfg, dtype=jnp.float32)
    cache = registry.cache_init(cfg, b, 16, jnp.float32)
    outs = []
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = registry.decode_step(params, cache, toks[:, t], pos, cfg,
                                         dtype=jnp.float32)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 2e-4, f"{arch}: decode mismatch {err}"


def test_whisper_decode_matches_teacher_forcing():
    from repro.models import whisper
    cfg = reduced(get_config("whisper-large-v3"))
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    b, sa, st_ = 2, 16, 10
    frames = jnp.asarray(rng.normal(size=(b, sa, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, st_)), jnp.int32)
    full = whisper.forward(params, dict(frames=frames, tokens=toks), cfg,
                           dtype=jnp.float32)
    enc = whisper.encode(params, frames, cfg)
    cache = whisper.prefill_cross(params, enc, cfg, s_dec=12)
    outs = []
    for t in range(st_):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = whisper.decode_step(params, cache, toks[:, t], pos, cfg,
                                        dtype=jnp.float32)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 2e-4


def test_scan_unroll_equivalence():
    """unroll=2 (the dry-run's cost probe) must not change the math."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(KEY, cfg)
    batch = _batch(cfg)
    l1 = registry.forward(params, batch, cfg, dtype=jnp.float32, unroll=1)
    l2 = registry.forward(params, batch, cfg, dtype=jnp.float32, unroll=2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_moe_routing_selects_topk():
    from repro.models import layers
    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")),
                              capacity_factor=8.0)
    p = layers.moe_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    y = layers.moe(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))


def test_local_attention_matches_full_within_window():
    """With window >= seq, local attention == global causal attention."""
    from repro.models import layers
    cfg = dataclasses.replace(reduced(get_config("recurrentgemma-9b")),
                              window=32)
    p = layers.attn_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    yl = layers.local_attention(p, x, cfg, pos)
    yg = layers.attention(p, x, cfg, pos, causal=True)
    np.testing.assert_allclose(np.asarray(yl), np.asarray(yg), atol=1e-5)


def test_mamba_ssd_chunking_invariance():
    """SSD output must not depend on the chunk size."""
    from repro.models import ssm
    cfg = reduced(get_config("mamba2-1.3b"))
    p = ssm.mamba_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model)) * 0.1
    y8 = ssm.mamba_forward(p, x, dataclasses.replace(cfg, ssm_chunk=8))
    y4 = ssm.mamba_forward(p, x, dataclasses.replace(cfg, ssm_chunk=4))
    y16 = ssm.mamba_forward(p, x, dataclasses.replace(cfg, ssm_chunk=16))
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y4), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=1e-4)


def test_param_count_sane():
    cfg = get_config("llama2-7b")
    n = cfg.param_count()
    assert 6.0e9 < n < 7.5e9
    moe = get_config("olmoe-1b-7b")
    assert moe.active_param_count() < moe.param_count()
