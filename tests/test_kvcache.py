"""Paged, quantized KV-cache subsystem: kernel backend parity, cache-mode
parity against the dense oracle (attention + recurrent families), scheduler
slot churn with block recycling, and the analytic byte accounting."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.kernels import kv_cache as kvk
from repro.models import registry
from repro.serving import kvcache
from repro.serving.scheduler import ContinuousBatcher, Request

KV_BACKENDS = ("xla", "pallas")
PAGED_KINDS = ("paged", "paged_q8", "paged_q8c", "paged_glvq")
# round-trip reconstruction tolerance per codec (values ~N(0,1)):
# raw = exact, int8 ~ amax/256, int4 lattice ~ amax/14
ROUNDTRIP_TOL = {"paged": 1e-6, "paged_q8": 0.05, "paged_q8c": 0.05,
                 "paged_glvq": 0.4}


# ---------------------------------------------------------------------------
# kernel-level: append/gather backend parity + quantization round trip
# ---------------------------------------------------------------------------

def _disjoint_table(rng, slots, bps):
    perm = rng.permutation(np.arange(1, 1 + slots * bps))
    return jnp.asarray(perm.reshape(slots, bps), jnp.int32)


@pytest.mark.parametrize("mode", PAGED_KINDS)
def test_kv_kernel_backend_parity(mode):
    rng = np.random.default_rng(3)
    b, bps, bs, kv, hd = 3, 3, 4, 2, 16
    table = _disjoint_table(rng, b, bps)
    caches = {be: kvk.pool_init(1 + b * bps, bs, kv, hd, jnp.float32, mode)
              for be in KV_BACKENDS}
    written = {}
    for t in range(bps * bs - 1):
        k = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        bids = table[:, t // bs]
        offs = jnp.full((b,), t % bs, jnp.int32)
        for be in KV_BACKENDS:
            caches[be] = kvk.append(caches[be], k, v, bids, offs,
                                    mode=mode, backend=be)
        written[t] = (np.asarray(k), np.asarray(v))
    outs = {be: kvk.gather(caches[be], table, mode=mode, backend=be,
                           out_dtype=jnp.float32) for be in KV_BACKENDS}
    for i in range(2):
        np.testing.assert_allclose(np.asarray(outs["xla"][i]),
                                   np.asarray(outs["pallas"][i]), atol=1e-6)
    # round trip: exact for raw paged, codec-bounded for the quantized modes
    tol = ROUNDTRIP_TOL[mode]
    for i in range(2):
        g = np.asarray(outs["xla"][i])
        for t, vals in written.items():
            np.testing.assert_allclose(g[:, t], vals[i], atol=tol)


def test_kv_backend_registry_and_env(monkeypatch):
    assert set(KV_BACKENDS) <= set(kvk.kv_backends())
    monkeypatch.setenv("REPRO_KV_BACKEND", "xla")
    assert kvk.resolve_kv_backend() == "xla"
    monkeypatch.setenv("REPRO_KV_BACKEND", "nope")
    with pytest.raises(ValueError):
        kvk.resolve_kv_backend()
    monkeypatch.delenv("REPRO_KV_BACKEND")
    assert kvk.resolve_kv_backend() in kvk.kv_backends()
    with pytest.raises(ValueError):
        kvk.resolve_kv_backend("also_nope")


def test_kv_companding_helps_heavy_tails():
    """The mu-law path spends its code grid near zero: for heavy-tailed
    values (most mass small, rare spikes setting the scale), companded int8
    must reconstruct the typical value better than linear int8."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_t(2, size=(64, 4, 32)) * 0.05, jnp.float32)
    err = {}
    for mode in ("paged_q8", "paged_q8c"):
        codes, amax = kvk.kv_quantize(x, mode)
        back = kvk.kv_dequantize(codes, amax, mode, jnp.float32)
        res = np.abs(np.asarray(back) - np.asarray(x))
        err[mode] = np.median(res)
    assert err["paged_q8c"] < err["paged_q8"]


# ---------------------------------------------------------------------------
# allocator / table bookkeeping
# ---------------------------------------------------------------------------

def test_block_allocator_exhaustion_and_recycling():
    alloc = kvcache.BlockAllocator(4)            # blocks 1..3 usable
    ids = [alloc.alloc() for _ in range(3)]
    assert sorted(ids) == [1, 2, 3] and alloc.free_blocks == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        alloc.alloc()
    alloc.free(ids[:2])
    assert alloc.free_blocks == 2
    again = alloc.alloc()
    assert again in ids[:2] and alloc.recycled == 1


def test_block_allocator_double_free_raises():
    """A double-free would hand the same block to two live slots and corrupt
    cross-request KV history — it must raise, not silently re-list."""
    alloc = kvcache.BlockAllocator(5)            # blocks 1..4 usable
    ids = [alloc.alloc() for _ in range(3)]
    alloc.free(ids[:1])
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free(ids[:1])
    assert alloc.free_blocks == 2                # state unchanged by the raise
    # the whole batch validates before any mutation: a bad id mid-list must
    # not leave earlier ids half-released (or the release retry would then
    # double-free spuriously)
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free([ids[1], ids[2], ids[2]])
    assert alloc.free_blocks == 2
    alloc.free(ids[1:])                          # retry succeeds atomically
    assert alloc.free_blocks == 4
    # freeing a block that was never handed out is the same corruption
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free([4])
    # scratch block 0 is silently skipped (idle rows point at it)
    alloc.free([0])
    with pytest.raises(ValueError, match="out-of-range"):
        alloc.free([7])
    # legitimate free -> realloc -> free cycles still work
    bid = alloc.alloc()
    alloc.free([bid])
    assert bid in [alloc.alloc() for _ in range(alloc.free_blocks)]


def test_slot_pages_lazy_grant_and_release():
    layout = kvcache.PageLayout.plan(s_cache=32, slots=2, block_size=8)
    assert layout.blocks_per_slot == 4 and layout.num_blocks == 9
    pages = kvcache.SlotPages(2, layout)
    pages.ensure(0, 0)
    assert pages.counts[0] == 1                  # only the first block
    pages.ensure(0, 7)
    assert pages.counts[0] == 1                  # same block, no new grant
    pages.ensure(0, 8)
    assert pages.counts[0] == 2                  # crossed a block boundary
    used = pages.alloc.used_blocks
    pages.release(0)
    assert pages.alloc.used_blocks == used - 2
    assert (pages.table[0] == 0).all()           # row back to scratch


# ---------------------------------------------------------------------------
# model-level parity: paged caches vs the dense oracle
# ---------------------------------------------------------------------------

def _teacher_forced_logits(params, cfg, tokens, cache_kind, s_cache=16,
                           block_size=4):
    """Drive the same token/position stream through decode_step and stack
    per-step logits.  Paged kinds use a static contiguous table."""
    b = tokens.shape[0]
    cache = registry.cache_init(cfg, b, s_cache, jnp.float32,
                                cache_kind=cache_kind, block_size=block_size)
    if cache_kind != "dense":
        cache["table"] = kvcache.static_table(b, -(-s_cache // block_size))
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = registry.decode_step(
            params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32), cfg,
            dtype=jnp.float32, cache_kind=cache_kind)
        outs.append(np.asarray(logits))
    return np.stack(outs, axis=1)                # [B, T, V]


@pytest.mark.parametrize("arch", ["llama2-7b", "recurrentgemma-9b"])
def test_paged_cache_matches_dense_oracle(arch):
    """Raw paged blocks are a pure relayout: logits must match the dense
    cache to float tolerance on a dense-attention AND a recurrent family."""
    cfg = reduced(get_config(arch))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    ref = _teacher_forced_logits(params, cfg, tokens, "dense")
    out = _teacher_forced_logits(params, cfg, tokens, "paged")
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_paged_window_ring_matches_dense_on_odd_s_cache():
    """window > s_cache with s_cache not a block multiple: the paged ring
    modulus must follow min(window, s_cache) like the dense oracle, not the
    block-rounded pool capacity."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("recurrentgemma-9b")),
                              window=24)
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(23)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    s_cache, bs = 20, 16
    b = tokens.shape[0]

    def drive(kind):
        cache = registry.cache_init(cfg, b, s_cache, jnp.float32,
                                    cache_kind=kind, block_size=bs)
        if kind != "dense":
            cache["table"] = kvcache.static_table(b, -(-s_cache // bs))
        outs = []
        for t in range(tokens.shape[1]):
            logits, cache = registry.decode_step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32),
                cfg, dtype=jnp.float32, cache_kind=kind,
                s_cache=None if kind == "dense" else s_cache)
            outs.append(np.asarray(logits))
        return np.stack(outs, 1)

    np.testing.assert_allclose(drive("paged"), drive("dense"),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["paged_q8", "paged_q8c"])
@pytest.mark.parametrize("arch", ["llama2-7b", "recurrentgemma-9b"])
def test_quantized_cache_matches_dense_within_tolerance(arch, kind):
    cfg = reduced(get_config(arch))
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(11)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    ref = _teacher_forced_logits(params, cfg, tokens, "dense")
    out = _teacher_forced_logits(params, cfg, tokens, kind)
    # int8 history: bounded drift relative to the logit scale
    scale = np.abs(ref).max()
    assert np.abs(out - ref).max() < 0.05 * scale + 0.05


@pytest.mark.parametrize("arch", ["llama2-7b", "recurrentgemma-9b"])
def test_glvq_cache_matches_dense_within_tolerance(arch):
    """4-bit lattice history: coarser than int8, so the drift bound is
    wider — but it must stay bounded relative to the logit scale on both a
    dense-attention and a recurrent/sliding-window family."""
    cfg = reduced(get_config(arch))
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(11)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    ref = _teacher_forced_logits(params, cfg, tokens, "dense")
    out = _teacher_forced_logits(params, cfg, tokens, "paged_glvq")
    scale = np.abs(ref).max()
    assert np.abs(out - ref).max() < 0.3 * scale + 0.05


# ---------------------------------------------------------------------------
# scheduler: slot churn, recurrent resets, block recycling
# ---------------------------------------------------------------------------

def _sequential_generate(params, cfg, prompt, max_new, s_cache=32):
    """Reference: one request at a time through plain dense decode steps."""
    cache = registry.cache_init(cfg, 1, s_cache, jnp.float32)
    out = []
    for pos in range(len(prompt) + max_new - 1):
        t = prompt[pos] if pos < len(prompt) else out[-1]
        logits, cache = registry.decode_step(
            params, cache, jnp.asarray([t], jnp.int32),
            jnp.asarray([pos], jnp.int32), cfg, dtype=jnp.float32)
        if pos >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
        if len(out) >= max_new:
            break
    return out


def _churn(params, cfg, prompts, max_new=4, **kw):
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32,
                           dtype=jnp.float32, **kw)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new=max_new))
    done = cb.run()
    assert sorted(done) == list(range(len(prompts)))
    return done, cb


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b"])
def test_recurrent_families_continuous_batching(arch):
    """ssm / hybrid slot churn (claim -> retire -> re-claim) must match the
    sequential oracle: per-slot recurrent resets prevent state leakage."""
    cfg = reduced(get_config(arch))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, n)))
               for n in (3, 5, 2, 6, 4)]          # 5 requests through 2 slots
    ref = [_sequential_generate(params, cfg, p, 4) for p in prompts]
    kind = "paged" if cfg.family == "hybrid" else "dense"
    done, _ = _churn(params, cfg, prompts, cache_kind=kind, block_size=8)
    for i in range(len(prompts)):
        assert done[i].tokens == ref[i], (i, done[i].tokens, ref[i])


def test_paged_block_recycling_under_churn():
    """More requests than the pool could hold without freeing: retired
    slots' blocks must be recycled, and recycled blocks must not corrupt the
    new occupant's history."""
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(17)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, n)))
               for n in (6, 7, 5, 8, 6, 7)]
    ref = [_sequential_generate(params, cfg, p, 6) for p in prompts]
    done, cb = _churn(params, cfg, prompts, max_new=6,
                      cache_kind="paged", block_size=4)
    assert cb.pages.alloc.recycled > 0, "churn never recycled a freed block"
    for i in range(len(prompts)):
        assert done[i].tokens == ref[i], (i, done[i].tokens, ref[i])


def test_paged_pool_exhaustion_raises():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32,
                           dtype=jnp.float32, cache_kind="paged",
                           block_size=4, num_blocks=3)  # scratch + 2 blocks
    for i in range(2):
        cb.submit(Request(rid=i, prompt=[1, 2, 3], max_new=8))
    with pytest.raises(RuntimeError, match="exhausted"):
        cb.run()


def test_encdec_rejects_paged_cache():
    cfg = reduced(get_config("whisper-large-v3"))
    with pytest.raises(ValueError, match="dense"):
        registry.cache_init(cfg, 2, 16, jnp.float32, cache_kind="paged")


# ---------------------------------------------------------------------------
# analytic byte accounting (the benchmark's source of truth)
# ---------------------------------------------------------------------------

def test_unknown_cache_kind_typed_errors():
    """Satellite regression: an unknown cache kind must raise a typed
    ValueError NAMING the valid kinds at every entry layer — engine build,
    pool init, codec, and the analytic byte model — instead of silently
    falling through to a default codec."""
    from repro.serving.engine import EngineConfig
    with pytest.raises(ValueError, match="paged_glvq"):
        EngineConfig(cache_kind="paged_q4")
    with pytest.raises(ValueError, match="paged_glvq"):
        kvk.pool_init(4, 4, 2, 16, jnp.float32, "paged_q4")
    with pytest.raises(ValueError, match="paged_q8"):
        kvk.kv_quantize(jnp.zeros((1, 2, 8)), "paged_glvq")  # int8-only API
    with pytest.raises(ValueError, match="paged_q8"):
        kvk.kv_dequantize(jnp.zeros((1, 2, 8), jnp.int8),
                          jnp.zeros((1, 2)), "nope", jnp.float32)
    with pytest.raises(ValueError, match="available"):
        kvcache.cache_bytes(reduced(get_config("llama2-7b")), "paged_q4",
                            8, 16)


def test_bytes_per_token_glvq_beats_q8():
    """Acceptance bar: paged_glvq resident bytes/token <= 0.15x dense bf16
    at llama2-7b geometry (hd = 128, 4 bits: 64 B codes + 2 B amax per head
    position vs 512 B dense), and the codebook overhead is a flat per-model
    constant independent of sequence length."""
    cfg = get_config("llama2-7b")
    s_cache, seq = 4096, 2048
    dense = kvcache.bytes_per_token(cfg, "dense", seq, s_cache)
    q8 = kvcache.bytes_per_token(cfg, "paged_q8", seq, s_cache)
    glvq = kvcache.bytes_per_token(cfg, "paged_glvq", seq, s_cache)
    assert glvq <= 0.15 * dense
    assert glvq < q8
    bk = kvcache.codebook_bytes(cfg, "paged_glvq")
    assert bk > 0 and bk == kvcache.codebook_bytes(cfg, "paged_glvq")
    assert kvcache.codebook_bytes(cfg, "paged_q8") == 0
    # 3-bit packs tighter still
    assert kvcache.bytes_per_token(cfg, "paged_glvq", seq, s_cache,
                                   kv_bits=3) < glvq


def test_kv_codebook_calibration_roundtrip():
    """calibrate_kv on a reduced llama: the fitted book must survive
    save/load bit-exactly, graft into cache_init over the identity
    defaults, and never reconstruct the fit samples worse than the
    uncalibrated identity codec (per-head candidate selection)."""
    from repro.core.glvq import GLVQConfig
    from repro.data import calibration as cal
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    batches = [{"tokens": rng.integers(1, cfg.vocab, (2, 16))}]
    book = cal.calibrate_kv(params, batches, cfg, bits=4, chunk=8,
                            samples_per_head=64,
                            qcfg=GLVQConfig(d=4, bits=4, iters=6), seed=0)
    assert (book.bits, book.hd) == (4, cfg.hd)
    entries = [b for b in list(book.blocks) + list(book.tail)
               if b is not None]
    assert entries, "no attention layer was calibrated"
    for bk in entries:
        for n in kvk.GLVQ_BOOK_LEAVES:
            assert n in bk
        # G @ G^-1 == I per head
        g = bk["kg"].reshape(-1, book.d, book.d)
        gi = bk["kgi"].reshape(-1, book.d, book.d)
        np.testing.assert_allclose(np.einsum("kij,kjl->kil", g, gi),
                                   np.broadcast_to(np.eye(book.d),
                                                   g.shape), atol=1e-4)
    path = "/tmp/test_kv_codebook.npz"
    cal.save_kv_codebook(path, book)
    book2 = cal.load_kv_codebook(path)
    assert (book2.bits, book2.d, book2.hd) == (book.bits, book.d, book.hd)
    for a, b in zip(list(book.blocks) + list(book.tail),
                    list(book2.blocks) + list(book2.tail)):
        assert (a is None) == (b is None)
        if a is not None:
            for n in a:
                np.testing.assert_array_equal(a[n], b[n])
    # grafting: cache_init with the codebook must carry the fitted leaves
    from repro.serving.engine import EngineConfig
    ecfg = EngineConfig(dtype=jnp.float32, cache_kind="paged_glvq",
                        s_cache=16, block_size=4, kv_codebook=book2)
    assert ecfg.kv_bits == book.bits and ecfg.kv_d == book.d
    cache = registry.cache_init(cfg, 2, engine=ecfg)
    lay = cache["blocks"][0] if book.blocks[0] is not None else \
        cache["tail"][0]
    src = book.blocks[0] if book.blocks[0] is not None else book.tail[0]
    np.testing.assert_allclose(np.asarray(lay["kg"]), src["kg"], atol=1e-6)


def test_bytes_per_token_paged_q8_beats_dense():
    """Acceptance bar: paged_q8 resident bytes/token <= 0.3x dense bf16 at
    equal sequence length (sequences at half the serving max)."""
    cfg = get_config("llama2-7b")
    s_cache, seq = 4096, 2048
    dense = kvcache.bytes_per_token(cfg, "dense", seq, s_cache)
    q8 = kvcache.bytes_per_token(cfg, "paged_q8", seq, s_cache)
    assert q8 <= 0.3 * dense
    # full-length sequences: still ~2x from int8 alone
    assert kvcache.bytes_per_token(cfg, "paged_q8", s_cache, s_cache) \
        <= 0.55 * kvcache.bytes_per_token(cfg, "dense", s_cache, s_cache)


def test_window_caps_local_layer_accounting():
    """Sliding-window layers retain min(window, s_cache) positions, so the
    hybrid family's dense bytes must not scale with s_cache alone."""
    cfg = get_config("recurrentgemma-9b")
    lengths = kvcache.attn_layer_lengths(cfg, 8192)
    assert set(lengths) == {min(cfg.window, 8192)}
    assert len(lengths) == cfg.n_repeats  # one local-attn layer per repeat


# ---------------------------------------------------------------------------
# tile padding (non-(8,128)-aligned block shapes) + chunk codec roundtrip
# ---------------------------------------------------------------------------

def test_padded_block_geom_units():
    assert kvk.padded_block_geom(12, 96) == (16, 128)
    assert kvk.padded_block_geom(8, 128) == (8, 128)
    assert kvk.padded_block_geom(16, 256) == (16, 256)
    # pad_to is the identity (same object) when already aligned
    x = jnp.zeros((2, 8, 4))
    assert kvk.pad_to(x, 1, 8) is x
    assert kvk.pad_to(x, 1, 16).shape == (2, 16, 4)


@pytest.mark.parametrize("mode", PAGED_KINDS)
def test_kv_kernel_parity_unaligned_blocks(mode, monkeypatch):
    """Regression: pallas append/append_chunk/gather on block_size=12,
    hd=96 (neither a multiple of the (8, 128) f32 tile) with forced tile
    padding must match the xla backend exactly."""
    monkeypatch.setenv("REPRO_KV_FORCE_TILE_PAD", "1")
    rng = np.random.default_rng(9)
    b, bps, bs, kv, hd, t = 2, 2, 12, 2, 96, 5
    table = _disjoint_table(rng, b, bps)
    caches = {be: kvk.pool_init(1 + b * bps, bs, kv, hd, jnp.float32, mode)
              for be in KV_BACKENDS}
    # single-token appends into the first block
    for tok in range(3):
        k = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        for be in KV_BACKENDS:
            caches[be] = kvk.append(caches[be], k, v, table[:, 0],
                                    jnp.full((b,), tok, jnp.int32),
                                    mode=mode, backend=be)
    # chunked append straddling into the second block, with a pad slot
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    idx = 3 + np.arange(t)
    bids = jnp.asarray(np.stack([np.asarray(table[s, idx // bs])
                                 for s in range(b)]), jnp.int32)
    offs = jnp.asarray(np.broadcast_to(idx % bs, (b, t)), jnp.int32)
    valid = jnp.asarray([[True] * t, [True] * (t - 1) + [False]])
    for be in KV_BACKENDS:
        caches[be] = kvk.append_chunk(caches[be], k, v, bids, offs, valid,
                                      table, mode=mode, backend=be)
    outs = {be: kvk.gather(caches[be], table, mode=mode, backend=be,
                           out_dtype=jnp.float32) for be in KV_BACKENDS}
    for i in range(2):
        np.testing.assert_allclose(np.asarray(outs["xla"][i]),
                                   np.asarray(outs["pallas"][i]), atol=1e-6)
    assert outs["pallas"][0].shape == (b, bps * bs, kv, hd)


def test_chunk_roundtrip_paged_is_identity():
    """cache_kind="paged" stores raw values: the in-flight chunk keys need
    no quantize->dequantize roundtrip, and the helper must return the very
    same arrays (no copy, no cast) when dtypes already match."""
    k = jnp.ones((2, 3, 2, 8), jnp.float32)
    v = jnp.zeros((2, 3, 2, 8), jnp.float32)
    rk, rv = kvk.chunk_roundtrip(k, v, mode="paged",
                                 store_dtype=jnp.float32,
                                 out_dtype=jnp.float32)
    assert rk is k and rv is v
    # differing store dtype: cast chain, still no quantization error
    rk2, _ = kvk.chunk_roundtrip(k, v, mode="paged",
                                 store_dtype=jnp.bfloat16,
                                 out_dtype=jnp.float32)
    assert rk2.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(rk2), np.asarray(k))


def test_chunk_roundtrip_quantized_matches_cache_codec():
    """The quantized kinds must see the chunk keys exactly as the cache
    would return them (quantize -> dequantize), or the window path's
    in-flight keys would disagree with their post-append reads."""
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.normal(size=(2, 3, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 3, 2, 8)), jnp.float32)
    for mode in ("paged_q8", "paged_q8c"):
        rk, rv = kvk.chunk_roundtrip(k, v, mode=mode,
                                     store_dtype=jnp.int8,
                                     out_dtype=jnp.float32)
        codes, amax = kvk.kv_quantize(k, mode)
        want = kvk.kv_dequantize(codes, amax, mode, jnp.float32)
        np.testing.assert_allclose(np.asarray(rk), np.asarray(want),
                                   atol=1e-6)
        assert float(jnp.abs(rk - k).max()) > 1e-6  # not the identity


def test_chunk_roundtrip_glvq_matches_cache_codec():
    """paged_glvq in-flight chunk keys must roundtrip through the SAME
    lattice codec (quantize -> word-pack -> unpack -> dequantize) the cache
    applies, with the identity default book when no codebook is given."""
    rng = np.random.default_rng(2)
    kv, hd = 2, 16
    k = jnp.asarray(rng.normal(size=(2, 3, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 3, kv, hd)), jnp.float32)
    spec = kvk.default_glvq_spec(hd)
    rk, rv = kvk.chunk_roundtrip(k, v, mode="paged_glvq",
                                 store_dtype=jnp.uint32,
                                 out_dtype=jnp.float32, glvq=spec)
    book = kvk.glvq_default_book(kv, spec)
    words, amax = kvk.glvq_quantize(k, book["kgi"], book["kmu"], spec)
    want = kvk.glvq_dequantize(words, amax, book["kg"], book["kmu"], spec,
                               jnp.float32)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(want), atol=1e-6)
    assert float(jnp.abs(rk - k).max()) > 1e-6  # not the identity


def test_glvq_identity_book_is_uniform_grid():
    """With the identity default book (G = I/hi, mu = 0) the lattice codec
    degenerates to plain per-token uniform signed-4-bit quantization — the
    uncalibrated fallback's semantics are exactly the int4 baseline."""
    rng = np.random.default_rng(3)
    hd, kv = 16, 2
    spec = kvk.default_glvq_spec(hd)
    book = kvk.glvq_default_book(kv, spec)
    x = jnp.asarray(rng.normal(size=(5, kv, hd)), jnp.float32)
    words, amax = kvk.glvq_quantize(x, book["kgi"], book["kmu"], spec)
    back = kvk.glvq_dequantize(words, amax, book["kg"], book["kmu"], spec,
                               jnp.float32)
    am = np.maximum(np.abs(np.asarray(x)).max(-1, keepdims=True), 1e-6)
    hi = spec.hi
    codes = np.clip(np.round(np.asarray(x) / am * hi), -hi - 1, hi)
    # the cache stores amax as f16 — the dequant rescale uses that rounding
    want = codes / hi * am.astype(np.float16).astype(np.float32)
    np.testing.assert_allclose(np.asarray(back), want, atol=1e-5)


def test_glvq_spec_validation_and_pool_inference():
    with pytest.raises(ValueError, match="bits"):
        kvk.GLVQSpec(bits=1, d=4, hd=16)
    with pytest.raises(ValueError, match="divide"):
        kvk.GLVQSpec(bits=4, d=3, hd=16)
    spec = kvk.default_glvq_spec(96)
    assert (spec.d, spec.hd, spec.bits) == (4, 96, 4)
    assert kvk.default_glvq_spec(6).d == 2    # 6 % 4 != 0 -> fall to 2
    cache = kvk.pool_init(4, 4, 2, 16, jnp.float32, "paged_glvq")
    got = kvk.glvq_spec_from_pool(cache)
    assert (got.bits, got.d, got.hd) == (4, 4, 16)
