"""Prefix/radix caching over the paged KV pool: refcounted allocator
lifecycle, radix match/insert/LRU-eviction units, aliased-table gather
identity at the kernel level, and end-to-end greedy parity prefix-cache
on vs off (bit-identical outputs) across every paged cache kind — plus
copy-on-write mid-block divergence and refcounted churn under pool
pressure with the debug sanitizer armed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import attention as attnk
from repro.kernels import kv_cache as kvk
from repro.models import registry
from repro.serving import kvcache
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvcache import BlockAllocator, PrefixCache
from repro.serving.scheduler import ContinuousBatcher, Request

PAGED_KINDS = ("paged", "paged_q8", "paged_q8c", "paged_glvq")
S_CACHE, BLOCK, CHUNK = 32, 4, 5


# ---------------------------------------------------------------------------
# allocator: refcount lifecycle
# ---------------------------------------------------------------------------

def test_allocator_refcount_lifecycle():
    alloc = BlockAllocator(6)                    # blocks 1..5 usable
    a = alloc.alloc()
    assert alloc.refcount(a) == 1 and alloc.live_blocks == 1
    alloc.incref(a)
    assert alloc.refcount(a) == 2
    assert alloc.decref(a) is False              # still one owner
    assert alloc.refcount(a) == 1
    assert alloc.decref(a) is True               # released (no retain hook)
    assert alloc.refcount(a) == 0 and a not in alloc._refs
    assert alloc.free_blocks == 5


def test_allocator_decref_below_zero_raises_and_counts():
    alloc = BlockAllocator(4)
    a = alloc.alloc()
    alloc.decref(a)
    with pytest.raises(RuntimeError, match="double free"):
        alloc.decref(a)
    assert alloc.double_free_rejected == 1
    # incref of a block that isn't resident is the mirror-image corruption
    with pytest.raises(RuntimeError, match="non-resident"):
        alloc.incref(a)


def test_allocator_park_and_resurrect():
    """retain() parks refcount-0 blocks; incref resurrects them; reclaim()
    runs under pool pressure before alloc gives up."""
    kept: set = set()
    alloc = BlockAllocator(3)                    # blocks 1..2 usable
    alloc.retain = kept.__contains__
    a = alloc.alloc()
    kept.add(a)
    alloc.decref(a)                              # parks, not freed
    assert alloc.parked_blocks == 1 and alloc.free_blocks == 1
    assert alloc.refcount(a) == 0
    alloc.incref(a)                              # resurrect from parked
    assert alloc.refcount(a) == 1 and alloc.parked_blocks == 0
    alloc.decref(a)                              # re-parks
    b = alloc.alloc()                            # one free block left: ok
    evicted = []

    def reclaim(n):
        for _ in range(n):
            if not alloc._parked:
                return len(evicted)
            bid = next(iter(alloc._parked))
            kept.discard(bid)
            alloc.release_parked(bid)
            evicted.append(bid)
        return len(evicted)

    alloc.reclaim = reclaim
    c = alloc.alloc()                            # pressure: evicts the park
    assert evicted == [a] and c == a
    alloc.free([b, c])


def test_release_parked_requires_parked():
    alloc = BlockAllocator(4)
    a = alloc.alloc()
    with pytest.raises(RuntimeError, match="not parked"):
        alloc.release_parked(a)


# ---------------------------------------------------------------------------
# radix index units
# ---------------------------------------------------------------------------

def _pc(num_blocks=16, bs=4, **kw):
    alloc = BlockAllocator(num_blocks)
    return PrefixCache(alloc, bs, **kw), alloc


def test_radix_insert_match_roundtrip():
    pc, alloc = _pc()
    b1, b2 = alloc.alloc(), alloc.alloc()
    assert pc.insert([1, 2, 3, 4, 5, 6, 7, 8], [b1, b2]) == 2
    chain, n = pc.match([1, 2, 3, 4, 5, 6, 7, 8, 9])
    assert chain == [b1, b2] and n == 8
    # shorter prompt matching only the first block
    chain, n = pc.match([1, 2, 3, 4, 99])
    assert chain == [b1] and n == 4
    # diverging inside block 1: partial boundary match
    chain, n = pc.match([1, 2, 3, 4, 5, 6, 99])
    assert chain == [b1, b2] and n == 6
    # no match at all
    assert pc.match([9, 9, 9, 9]) == ([], 0)


def test_radix_insert_dedup_and_double_register():
    pc, alloc = _pc()
    b1, b2, b3 = alloc.alloc(), alloc.alloc(), alloc.alloc()
    assert pc.insert([1, 2, 3, 4], [b1]) == 1
    # same path, different block: existing node wins, duplicate not indexed
    assert pc.insert([1, 2, 3, 4, 5, 6, 7, 8], [b2, b3]) == 1
    assert pc.resident_blocks == 2 and b2 not in pc.by_block
    # one block under two different paths is corruption
    with pytest.raises(RuntimeError, match="different token path"):
        pc.insert([7, 7, 7, 7], [b1])
    with pytest.raises(ValueError, match="exactly"):
        pc.insert([1, 2, 3], [b1])


def test_lru_eviction_ordering():
    """Least-recently-matched refcount-0 LEAF goes first; parents only
    after their children (paths stay intact)."""
    pc, alloc = _pc(num_blocks=32)
    ids = [alloc.alloc() for _ in range(4)]
    pc.insert([1, 2, 3, 4, 5, 6, 7, 8], ids[:2])     # chain A: a1 -> a2
    pc.insert([9, 9, 9, 9], [ids[2]])                # chain B
    pc.insert([8, 8, 8, 8], [ids[3]])                # chain C
    for b in ids:
        alloc.decref(b)                              # all parked
    pc.match([9, 9, 9, 9])                           # B most recent
    pc.match([8, 8, 8, 8])
    pc.match([1, 2, 3, 4])                           # touches a1 ONLY
    # LRU leaves: a2 (never matched since insert) then B then C; a1 is
    # not a leaf until a2 goes, and is the most recent anyway
    assert pc.evict(1) == 1 and ids[1] not in pc.by_block
    assert pc.evict(1) == 1 and ids[2] not in pc.by_block
    assert pc.evict(1) == 1 and ids[3] not in pc.by_block
    assert pc.evict(1) == 1 and ids[0] not in pc.by_block   # a1 last
    assert pc.evict(1) == 0 and alloc.free_blocks == 31
    assert pc.evictions == 4


def test_evict_skips_live_blocks():
    pc, alloc = _pc()
    b1 = alloc.alloc()
    pc.insert([1, 2, 3, 4], [b1])
    assert pc.evict(1) == 0                      # refcount 1: not evictable
    alloc.decref(b1)                             # parks (retain hook)
    assert alloc.parked_blocks == 1
    assert pc.evict(1) == 1 and alloc.free_blocks == 15


# ---------------------------------------------------------------------------
# kernels: aliased block tables are legal read-side inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", PAGED_KINDS)
def test_gather_identity_aliased_tables(mode):
    """Two slots whose tables alias the same blocks must gather the exact
    same K/V bytes — the read path the prefix cache relies on."""
    rng = np.random.default_rng(7)
    b, bps, bs, kv, hd = 2, 3, 4, 2, 16
    shared = jnp.asarray([1, 2, 3], jnp.int32)
    table = jnp.stack([shared, shared])          # both rows alias 1,2,3
    cache = kvk.pool_init(1 + 3, bs, kv, hd, jnp.float32, mode)
    for t in range(bps * bs):
        k = jnp.asarray(rng.normal(size=(1, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, kv, hd)), jnp.float32)
        cache = kvk.append(cache, k, v, shared[t // bs][None],
                           jnp.asarray([t % bs], jnp.int32),
                           mode=mode, backend="xla")
    for be in ("xla", "pallas"):
        ks, vs = kvk.gather(cache, table, mode=mode, backend=be,
                            out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(ks[0]), np.asarray(ks[1]))
        np.testing.assert_array_equal(np.asarray(vs[0]), np.asarray(vs[1]))
    # and the fused attention path: identical queries over aliased tables
    # give bit-identical outputs per backend
    q = jnp.asarray(rng.normal(size=(2, 1, 2 * kv, hd)), jnp.float32)
    q = q.at[1].set(q[0])
    pos = jnp.asarray([bps * bs - 1] * 2, jnp.int32)   # last query position
    lens = jnp.asarray([bps * bs] * 2, jnp.int32)      # appended history
    for be in attnk.attn_backends():
        out = attnk.paged_attention(q, cache, table, pos, lens, mode=mode,
                                    window=0, backend=be,
                                    out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


# ---------------------------------------------------------------------------
# end-to-end: greedy parity prefix-cache on vs off
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def llama():
    cfg = reduced(get_config("llama2-7b"))
    return cfg, registry.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def rgemma():
    cfg = reduced(get_config("recurrentgemma-9b"))
    return cfg, registry.init_params(jax.random.PRNGKey(1), cfg)


def _ecfg(kind, prefix, **kw):
    kw.setdefault("dtype", jnp.float32)
    kw.setdefault("s_cache", S_CACHE)
    kw.setdefault("block_size", BLOCK)
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("slots", 2)
    kw.setdefault("debug_checks", True)
    return EngineConfig(cache_kind=kind, prefix_cache=prefix, **kw)


def _serve(model, kind, prompts, prefix, **kw):
    cfg, params = model
    eng = ServingEngine(params, cfg, _ecfg(kind, prefix, **kw))
    outs = [list(eng.submit(p).result(max_steps=400).tokens)
            for p in prompts]
    return outs, eng


@pytest.mark.parametrize("kind", PAGED_KINDS)
def test_prefix_parity_greedy_llama(llama, kind):
    shared = list(range(1, 13))                  # 3 full blocks
    prompts = [shared + [50 + r, 60 + r] for r in range(3)]
    on, eng = _serve(llama, kind, prompts, True)
    off, _ = _serve(llama, kind, prompts, False)
    assert on == off                             # bit-identical greedy
    st = eng.prefix_cache_stats()
    assert st["hits"] == 2 and st["misses"] == 1
    assert st["tokens_reused"] == 2 * len(shared)


@pytest.mark.parametrize("kind", ("paged_q8", "paged_glvq"))
def test_prefix_cow_mid_block_divergence(llama, kind):
    """Prompts diverging mid-block force the copy-on-write boundary copy;
    outputs stay bit-identical to the cache-off run.  paged_glvq rides the
    same copy (uint32 word pools copy like any code pool; the codebook
    leaves are shared per-layer constants and stay out of it)."""
    shared = list(range(1, 15))                  # 14 tokens: 3.5 blocks
    prompts = [shared + [50 + r] for r in range(3)]
    on, eng = _serve(llama, kind, prompts, True)
    off, _ = _serve(llama, kind, prompts, False)
    assert on == off
    st = eng.prefix_cache_stats()
    assert st["cow_copies"] >= 1 and st["hits"] == 2


def test_prefix_full_prompt_match_still_samples(llama):
    """A prompt entirely contained in the cache must still prefill >= 1
    token (the clamp to len(prompt) - 1) so the first sample has logits."""
    p = list(range(1, 13))
    on, eng = _serve(llama, "paged", [p, p, p], True)
    off, _ = _serve(llama, "paged", [p, p, p], False)
    assert on == off and eng.prefix_cache_stats()["hits"] == 2


def test_recurrent_stack_disables_sharing_but_parity_holds(rgemma):
    """recurrentgemma carries recurrent + sliding-window state outside the
    pool: the engine must refuse to share (prefix stays None) and behave
    identically with the flag on."""
    shared = list(range(1, 13))
    prompts = [shared + [50 + r] for r in range(2)]
    on, eng = _serve(rgemma, "paged", prompts, True)
    off, _ = _serve(rgemma, "paged", prompts, False)
    assert eng.prefix_cache_stats() is None
    assert eng.batcher.prefix is None
    assert on == off


def test_prefix_hit_pre_advances_budget_view(llama):
    """A cache hit converts prefill work into reuse: the slot's prompt
    cursor starts at the reused offset, so TokenBudgetPolicy-style
    ``remaining`` sees only the un-cached tail."""
    cfg, params = llama
    eng = ServingEngine(params, cfg, _ecfg("paged", True))
    eng.submit(list(range(1, 13)) + [50]).result(max_steps=400)
    h = eng.submit(list(range(1, 13)) + [51])
    eng.step()                                   # claim happens here
    s = next(s for s in eng.batcher.slots if not s.free)
    assert s.req.rid == h.rid
    assert eng.prefix_cache_stats()["hits"] == 1
    h.result(max_steps=400)


def test_refcounted_churn_under_pressure(llama):
    """Many shared-prefix requests through a small pool with the sanitizer
    armed: refcounts must stay consistent every iteration, eviction must
    keep alloc from exhausting, and retiring the fleet returns the pool to
    parked-or-free with zero live blocks."""
    cfg, params = llama
    eng = ServingEngine(params, cfg, _ecfg("paged_q8c", True, slots=3))
    shared = list(range(1, 9))
    handles = [eng.submit(shared + [40 + r, 70 + r]) for r in range(8)]
    for h in handles:
        h.result(max_steps=1000)
    assert all(h.done for h in handles)
    alloc = eng.batcher.pages.alloc
    assert alloc.live_blocks == 0                # every slot retired
    st = eng.prefix_cache_stats()
    # the first wave (3 slots) claims against an empty trie concurrently;
    # every later request must hit
    assert st["hits"] >= 5 and st["misses"] <= 3
    assert st["resident_blocks"] == alloc.parked_blocks
    assert alloc.double_free_rejected == 0
    # metrics surface mirrors the live counters
    counters = eng.metrics_snapshot()["counters"]
    assert counters["serving_prefix_cache_hits_total"][""] == st["hits"]
    assert counters["serving_prefix_tokens_reused_total"][""] \
        == st["tokens_reused"]


def test_prefix_cache_off_has_no_index(llama):
    cfg, params = llama
    cb = ContinuousBatcher(params, cfg, _ecfg("paged", False))
    assert cb.prefix is None
    cb.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=2))
    cb.run(max_steps=40)
    assert cb.pages.alloc.parked_blocks == 0     # nothing retained
