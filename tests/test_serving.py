"""Continuous batching scheduler + supervised (restart-on-failure) training
+ elastic restore across device counts."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.optim import AdamWConfig
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _sequential_generate(params, cfg, prompt, max_new, s_cache=32):
    """Reference: one request at a time through plain decode steps."""
    cache = registry.cache_init(cfg, 1, s_cache, jnp.float32)
    out = []
    tok = None
    for pos in range(len(prompt) + max_new - 1):
        t = prompt[pos] if pos < len(prompt) else out[-1]
        logits, cache = registry.decode_step(
            params, cache, jnp.asarray([t], jnp.int32),
            jnp.asarray([pos], jnp.int32), cfg, dtype=jnp.float32)
        if pos >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
        if len(out) >= max_new:
            break
    return out


def test_continuous_batching_matches_sequential(tiny_lm):
    cfg, params = tiny_lm
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab, n)))
               for n in (3, 5, 2, 7, 4)]
    max_new = 4
    ref = [_sequential_generate(params, cfg, p, max_new) for p in prompts]

    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=32,
                           dtype=jnp.float32)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new=max_new))
    done = cb.run()
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        assert done[i].tokens == ref[i], (i, done[i].tokens, ref[i])


def test_continuous_batching_more_requests_than_slots(tiny_lm):
    cfg, params = tiny_lm
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=16,
                           dtype=jnp.float32)
    for i in range(7):
        cb.submit(Request(rid=i, prompt=[1 + i], max_new=3))
    done = cb.run()
    assert sorted(done) == list(range(7))
    assert all(len(r.tokens) == 3 for r in done.values())


def test_scheduler_accepts_recurrent_families():
    """Recurrent families batch continuously now (per-slot state resets on
    claim); deep churn parity lives in test_kvcache.py."""
    cfg = reduced(get_config("mamba2-1.3b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(params, cfg, slots=2, s_cache=16)
    cb.submit(Request(rid=0, prompt=[1, 2], max_new=2))
    done = cb.run()
    assert len(done[0].tokens) == 2


def test_scheduler_rejects_unknown_cache_kind(tiny_lm):
    cfg, params = tiny_lm
    with pytest.raises(ValueError, match="cache_kind"):
        ContinuousBatcher(params, cfg, slots=2, s_cache=16,
                          cache_kind="blocky")


# ---------------------------------------------------------------------------
# supervisor: crash -> restart -> identical result
# ---------------------------------------------------------------------------

def test_supervised_train_recovers_from_failures(tmp_path):
    from repro.launch.supervisor import supervised_train
    cfg = reduced(get_config("llama2-7b"))
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=0)
    kw = dict(steps=20, batch=2, seq=16, ckpt_every=5)
    p_clean, _, r0, losses_clean = supervised_train(
        cfg, opt_cfg, ckpt_dir=str(tmp_path / "clean"), **kw)
    assert r0 == 0
    p_crashy, _, r1, losses_crashy = supervised_train(
        cfg, opt_cfg, ckpt_dir=str(tmp_path / "crashy"),
        fail_at=(7, 13), **kw)
    assert r1 == 2
    diff = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p_clean, p_crashy))
    assert diff < 1e-6            # bit-exact recovery
    assert losses_crashy[19] == losses_clean[19]


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    from repro.launch.supervisor import SimulatedFailure, supervised_train
    cfg = reduced(get_config("llama2-7b"))
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=0)
    with pytest.raises(SimulatedFailure):
        supervised_train(cfg, opt_cfg, steps=8, batch=2, seq=8,
                         ckpt_dir=str(tmp_path), ckpt_every=100,
                         fail_at=(1, 1, 1), max_restarts=0)


# ---------------------------------------------------------------------------
# elastic restore: checkpoint from an 8-device mesh onto a 4-device mesh
# ---------------------------------------------------------------------------

_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import registry
    from repro.ckpt.manager import CheckpointManager
    from repro.ckpt.elastic import elastic_restore, plan_elastic
    from repro.data.synthetic import make_batch

    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(sys.argv[1] if len(sys.argv) > 1 else "/tmp/ec", keep=2)
    mgr.save(3, params)

    # "node failure": only 4 devices survive -> new (1, 4) mesh
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    step, placed = elastic_restore(mgr, params, mesh)
    plan = plan_elastic(16, mesh)
    batch = make_batch(cfg, 4, 8, 0)
    with mesh:
        loss = registry.loss_fn(placed, batch, cfg, dtype=jnp.float32,
                                remat=False)
    print(json.dumps(dict(step=step, loss=float(loss),
                          accum=plan.accum_steps,
                          per_replica=plan.per_replica_batch)))
""")


def test_elastic_restore_subprocess(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT, str(tmp_path)],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["step"] == 3
    assert np.isfinite(res["loss"])
    assert res["per_replica"] * 1 * res["accum"] >= 16
