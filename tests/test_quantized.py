"""Quantized-weight containers, packed decode paths, whole-model PTQ."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core import GLVQConfig, quantize_layer, dequantize_layer
from repro.core.quantized import (QuantLinearMeta, decode_xla, pack_layer,
                                  quantize_param_tree, quantized_param_shapes,
                                  materialize_tree, segment_layer,
                                  decode_segments)
from repro.core.sdba import sdba
from repro.models import registry


def _layer(seed=0, k=128, n=32):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_t(3, size=(k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, 128)), jnp.float32)
    return w, x @ x.T


def test_packed_decode_equals_reference_dequant():
    w, h = _layer()
    cfg = GLVQConfig(d=8, bits=3, iters=10)
    q = quantize_layer(w, h, cfg)
    ref = dequantize_layer(q, cfg)
    payload = pack_layer(q, cfg, 3)
    meta = QuantLinearMeta(k=w.shape[0], n=w.shape[1], bits=3, d=8,
                           group_size=128)
    out = decode_xla(payload, meta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_segmented_mixed_bits_roundtrip():
    w, h = _layer(seed=1, k=512)
    cfg = GLVQConfig(d=8, bits=2, iters=5)
    bits = jnp.asarray(sdba(w, h, 128, 2))
    q = quantize_layer(w, h, cfg, bits)
    segs = segment_layer(q, cfg)
    assert abs(segs.avg_bits() - 2.0) < 1e-9
    out = decode_segments(segs)
    ref = dequantize_layer(q, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_payload_bytes_accounting():
    meta = QuantLinearMeta(k=4096, n=4096, bits=2, d=16, group_size=128)
    dense = 4096 * 4096 * 2                     # bf16
    ratio = meta.payload_bytes() / dense
    assert 0.12 < ratio < 0.14                  # ~2/16 + side info


def test_quantize_param_tree_and_materialize():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=8, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)
    assert meta, "nothing was quantized"
    dense = materialize_tree(qparams, meta, jnp.float32)
    # same tree structure as original
    jax.tree.map(lambda a, b: None, params, dense)
    # decoded weights approximate the originals
    err = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.mean((a - b) ** 2)) / (float(jnp.var(a)) + 1e-9),
        params, dense))
    assert err < 0.15


def test_quantized_decode_step_runs_and_tracks_dense():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=8, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)
    cache = registry.cache_init(cfg, 2, 8, jnp.float32)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    lg_q, _ = registry.decode_step(qparams, cache, tok, pos, cfg,
                                   dtype=jnp.float32, qmeta=meta)
    # fake-quant reference: dense weights decoded outside
    dense = materialize_tree(qparams, meta, jnp.float32)
    cache = registry.cache_init(cfg, 2, 8, jnp.float32)
    lg_d, _ = registry.decode_step(dense, cache, tok, pos, cfg,
                                   dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_q), np.asarray(lg_d),
                               rtol=1e-4, atol=1e-4)


def test_quantized_shapes_sds_matches_real_payloads():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, meta_r = quantize_param_tree(params, cfg=qcfg)
    sds = jax.eval_shape(lambda: params)
    qsds, meta_s = quantized_param_shapes(sds, bits=4, d=8, group_size=32)
    real_shapes = jax.tree.map(lambda a: a.shape, qparams)
    sds_shapes = jax.tree.map(lambda a: a.shape, qsds)
    assert real_shapes == sds_shapes
    assert set(meta_r) == set(meta_s)


def test_quantization_error_shrinks_with_bits_model_level():
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    errs = {}
    for bits in (2, 4):
        qcfg = GLVQConfig(d=8, bits=bits, iters=8, group_size=32)
        qparams, meta = quantize_param_tree(params, cfg=qcfg)
        dense = materialize_tree(qparams, meta, jnp.float32)
        errs[bits] = jax.tree.reduce(lambda a, b: a + b, jax.tree.map(
            lambda a, b: float(jnp.sum((a - b) ** 2)), params, dense))
    assert errs[4] < errs[2]
