"""Quantized-execution engine: QuantTensor dispatch + backend parity.

The acceptance bar for the engine refactor: ``pallas_fused``, ``xla_decode``
and ``reference`` produce the same y = x @ dequant(W) (atol-bounded — the
mu-law expand is exponential, so tolerance scales with output magnitude) over
uniform and mixed-bit (SDBA-segmented) layers and stacked payloads.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GLVQConfig, QuantTensor, qtensor, quantize_layer
from repro.core.testing import synthetic_payload
from repro.core.quantized import (QuantLinearMeta, decode_segments,
                                  materialize_tree, quantize_param_tree,
                                  segment_layer)
from repro.core.sdba import sdba
from repro.kernels import ops

BACKENDS = ("reference", "xla_decode", "pallas_fused")


_payload = synthetic_payload


def _assert_close(a, b, ref):
    tol = 2e-6 * float(np.abs(ref).max()) + 1e-5
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=tol)


# --- backend registry --------------------------------------------------------

def test_registry_exposes_all_backends():
    assert set(BACKENDS) <= set(ops.matmul_backends())


def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_QUANT_BACKEND", "reference")
    assert ops.resolve_backend() == "reference"
    monkeypatch.setenv("REPRO_QUANT_BACKEND", "nope")
    with pytest.raises(ValueError):
        ops.resolve_backend()
    monkeypatch.delenv("REPRO_QUANT_BACKEND")
    assert ops.resolve_backend() in ops.matmul_backends()
    with pytest.raises(ValueError):
        ops.resolve_backend("also_nope")


# --- uniform-bit parity ------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 3, 4])
def test_backend_parity_uniform(bits):
    rng = np.random.default_rng(bits)
    k, n, m, d = 256, 320, 8, 8
    meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
    qt = QuantTensor.from_payload(_payload(rng, k, n, bits, d), meta)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    ys = {b: np.asarray(qt.matmul(x, backend=b, out_dtype=jnp.float32))
          for b in BACKENDS}
    for b in BACKENDS[1:]:
        _assert_close(ys[b], ys["reference"], ys["reference"])


@pytest.mark.parametrize("bits", [3])
def test_backend_parity_unaligned_n(bits):
    """bits=3 with small N exercises the word-padding path in glvq_matmul."""
    rng = np.random.default_rng(7)
    k, n, d = 128, 64, 8
    meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
    qt = QuantTensor.from_payload(_payload(rng, k, n, bits, d), meta)
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    ys = {b: np.asarray(qt.matmul(x, backend=b, out_dtype=jnp.float32))
          for b in BACKENDS}
    for b in BACKENDS[1:]:
        _assert_close(ys[b], ys["reference"], ys["reference"])


# --- mixed-bit (SDBA) parity -------------------------------------------------

@pytest.mark.parametrize("avg_bits", [2, 3])
def test_backend_parity_mixed_bits(avg_bits):
    rng = np.random.default_rng(avg_bits * 11)
    k, n, m = 512, 320, 8
    w = np.asarray(rng.standard_t(3, size=(k, n)) * 0.02)
    for gi, f in enumerate((30.0, 1.0, 1.0, 0.03)):   # spread group salience
        w[gi * 128:(gi + 1) * 128] *= f
    w = jnp.asarray(w, jnp.float32)
    xc = jnp.asarray(rng.normal(size=(k, 128)), jnp.float32)
    h = xc @ xc.T
    cfg = GLVQConfig(d=8, bits=avg_bits, iters=5)
    bits = jnp.asarray(sdba(w, h, 128, avg_bits))
    q = quantize_layer(w, h, cfg, bits)
    segs = segment_layer(q, cfg)
    assert len(segs.segments) > 1, "SDBA produced a uniform layer"
    qt = QuantTensor.from_segments(segs)
    assert qt.is_mixed and abs(qt.avg_bits() - avg_bits) < 1e-9

    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dense_ref = np.asarray(x @ decode_segments(segs))
    for b in BACKENDS:
        y = np.asarray(qt.matmul(x, backend=b, out_dtype=jnp.float32))
        _assert_close(y, dense_ref, dense_ref)


# --- stacked payloads --------------------------------------------------------

@pytest.mark.parametrize("zipped", [False, True])
def test_backend_parity_stacked(zipped):
    rng = np.random.default_rng(42)
    lead, k, n, m, bits, d = 3, 128, 320, 8, 4, 8
    meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
    payloads = [_payload(rng, k, n, bits, d) for _ in range(lead)]
    stacked = {key: jnp.stack([p[key] for p in payloads])
               for key in payloads[0]}
    qt = QuantTensor.from_payload(stacked, meta)
    assert qt.shape == (lead, k, n)
    if zipped:
        x = jnp.asarray(rng.normal(size=(lead, m, k)), jnp.float32)
        per_slice = [np.asarray(
            QuantTensor.from_payload(payloads[i], meta).matmul(
                x[i], backend="reference", out_dtype=jnp.float32))
            for i in range(lead)]
    else:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        per_slice = [np.asarray(
            QuantTensor.from_payload(payloads[i], meta).matmul(
                x, backend="reference", out_dtype=jnp.float32))
            for i in range(lead)]
    ref = np.stack(per_slice)
    for b in BACKENDS:
        y = np.asarray(qt.matmul(x, backend=b, out_dtype=jnp.float32))
        assert y.shape == (lead, m, n)
        _assert_close(y, ref, ref)


# --- QuantTensor semantics ---------------------------------------------------

def test_qtensor_is_a_pytree_and_scan_slices_it():
    rng = np.random.default_rng(5)
    lead, k, n, bits, d = 2, 128, 320, 4, 8
    meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
    payloads = [_payload(rng, k, n, bits, d) for _ in range(lead)]
    stacked = {key: jnp.stack([p[key] for p in payloads])
               for key in payloads[0]}
    qt = QuantTensor.from_payload(stacked, meta)

    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert all(isinstance(l, jax.Array) for l in leaves)
    assert isinstance(jax.tree_util.tree_unflatten(treedef, leaves),
                      QuantTensor)

    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)

    @jax.jit
    def run(x, qt):
        def body(x, qt_i):      # scan slices the stacked payload arrays
            return qt_i.matmul(x, backend="xla_decode",
                               out_dtype=jnp.float32) @ jnp.ones((n, k)), None
        out, _ = jax.lax.scan(body, x, qt)
        return out

    out = run(x, qt)
    assert out.shape == (4, k) and bool(jnp.all(jnp.isfinite(out)))


def test_rmatmul_astype_idiom():
    """`x @ w.astype(x.dtype)` — the dense-layer idiom — works on QuantTensor."""
    rng = np.random.default_rng(6)
    k, n, bits, d = 128, 320, 2, 8
    meta = QuantLinearMeta(k=k, n=n, bits=bits, d=d, group_size=128)
    qt = QuantTensor.from_payload(_payload(rng, k, n, bits, d), meta)
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    y = x @ qt.astype(x.dtype)
    assert y.dtype == x.dtype and y.shape == (4, n)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(qt.matmul(x)), rtol=1e-6)


def test_wrap_tree_matches_materialize_tree():
    from repro.configs import get_config, reduced
    from repro.models import registry
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)

    wrapped = qtensor.wrap_tree(qparams, meta)
    qts = [l for l in jax.tree_util.tree_leaves(
        wrapped, is_leaf=lambda x: isinstance(x, QuantTensor))
        if isinstance(l, QuantTensor)]
    assert qts, "wrap_tree converted nothing"

    dense_a = qtensor.dense_tree(qparams, meta, jnp.float32)
    dense_b = materialize_tree(qparams, meta, jnp.float32)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        dense_a, dense_b)


def test_whisper_quantized_engine_parity():
    """The encoder-decoder family routes through the QuantTensor engine like
    every other family (registry no longer strips qmeta/backend): quantized
    forward + decode must reproduce the materialized-dense-weight logits."""
    from repro.configs import get_config, reduced
    from repro.models import registry
    cfg = reduced(get_config("whisper-large-v3"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=2, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)
    assert meta, "no whisper weights were quantized"
    dense = qtensor.dense_tree(qparams, meta, jnp.float32)

    rng = np.random.default_rng(0)
    b, s_a = 2, 16
    s_t = max(s_a // cfg.frontend_stride, 8)
    batch = dict(
        frames=jnp.asarray(rng.normal(size=(b, s_a, cfg.d_model)), jnp.float32),
        tokens=jnp.asarray(rng.integers(1, cfg.vocab, (b, s_t)), jnp.int32))
    ref = np.asarray(registry.forward(dense, batch, cfg, dtype=jnp.float32))
    out = np.asarray(registry.forward(qparams, batch, cfg, dtype=jnp.float32,
                                      qmeta=meta, backend="xla_decode"))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    cache = registry.cache_init(cfg, b, 8, jnp.float32)
    tok = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    lr, _ = registry.decode_step(dense, cache, tok, pos, cfg,
                                 dtype=jnp.float32)
    lq, _ = registry.decode_step(qparams, cache, tok, pos, cfg,
                                 dtype=jnp.float32, qmeta=meta,
                                 backend="xla_decode")
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lr),
                               rtol=1e-5, atol=1e-5)

    # serving prefill: quantized cross-K/V, batch deliberately != n_layers
    # (exercises the stacked broadcast path on the xla_decode shortcut)
    from repro.models import whisper
    b3 = 3
    enc = jnp.asarray(rng.normal(size=(b3, s_a, cfg.d_model)), jnp.float32)
    cq = whisper.prefill_cross(qparams, enc, cfg, 8, qmeta=meta,
                               backend="xla_decode")
    cd = whisper.prefill_cross(dense, enc, cfg, 8)
    np.testing.assert_allclose(np.asarray(cq["cross_k"]),
                               np.asarray(cd["cross_k"]),
                               rtol=1e-5, atol=1e-5)


def test_decode_step_backend_parity_model_level():
    """The model decode path dispatches through QuantTensor.matmul: the
    reference backend must reproduce the default backend's logits."""
    from repro.configs import get_config, reduced
    from repro.models import registry
    cfg = reduced(get_config("llama2-7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = GLVQConfig(d=8, bits=4, iters=4, group_size=32)
    qparams, meta = quantize_param_tree(params, cfg=qcfg)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)

    def logits(backend):
        cache = registry.cache_init(cfg, 2, 8, jnp.float32)
        lg, _ = registry.decode_step(qparams, cache, tok, pos, cfg,
                                     dtype=jnp.float32, qmeta=meta,
                                     backend=backend)
        return np.asarray(lg)

    ref = logits("reference")
    np.testing.assert_allclose(logits(None), ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(logits("xla_decode"), ref, rtol=1e-4, atol=1e-4)
