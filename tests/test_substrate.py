"""Optimizer, schedules, gradient compression, checkpointing, data, elastic."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import AdamWConfig, adamw_init, adamw_update, lr_at_step, \
    clip_by_global_norm
from repro.optim.compression import ef_compress, ef_decompress, ef_round
from repro.ckpt.manager import CheckpointManager
from repro.data.synthetic import markov_tokens, token_batches, make_batch
from repro.configs import get_config, reduced


# --- optimizer ----------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, schedule="const")
    params = dict(w=jnp.asarray([5.0, -3.0]))
    state = adamw_init(params)
    for _ in range(100):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="wsd", wsd_decay_frac=0.2, min_lr_frac=0.1)
    lrs = [float(lr_at_step(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0                       # warmup from 0
    assert abs(lrs[10] - 1.0) < 1e-6           # warmed up
    assert abs(lrs[50] - 1.0) < 1e-6           # stable plateau (the "S" in WSD)
    assert lrs[99] < 0.2                       # decayed
    assert lrs[85] > lrs[95]                   # decay is monotone


def test_cosine_schedule_endpoints():
    cfg = AdamWConfig(lr=2.0, warmup_steps=0, total_steps=100,
                      schedule="cosine", min_lr_frac=0.1)
    assert abs(float(lr_at_step(cfg, jnp.asarray(100))) - 0.2) < 1e-5


def test_grad_clip():
    g = dict(a=jnp.full((10,), 10.0))
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4
    assert float(norm) > 30.0


# --- gradient compression -------------------------------------------------------

def test_ef_compress_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=1024), jnp.float32)
    q, s = ef_compress(g)
    err = float(jnp.max(jnp.abs(ef_decompress(q, s) - g)))
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_converges():
    """With EF, the accumulated applied-gradient matches the true sum."""
    rng = np.random.default_rng(1)
    true = jnp.asarray(rng.normal(size=256), jnp.float32) * 1e-3
    res = jnp.zeros_like(true)
    applied = jnp.zeros_like(true)
    for _ in range(50):
        g, res = ef_round(true, res)
        applied = applied + g
    # mean applied per-round ~ true gradient (residual is bounded)
    np.testing.assert_allclose(np.asarray(applied / 50), np.asarray(true),
                               atol=float(jnp.max(jnp.abs(true))) / 20)


# --- checkpoint manager ----------------------------------------------------------

def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return dict(a=jax.random.normal(k, (4, 8)),
                nested=dict(b=jnp.arange(7, dtype=jnp.int32)),
                lst=[jnp.ones((2,)), jnp.zeros((3,))])


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree(0)
    mgr.save(10, t)
    out = mgr.restore(10, jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, out)


def test_ckpt_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_ckpt_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, _tree(5))
    # simulate a crashed writer: directory without COMMIT
    bad = tmp_path / "step_9"
    bad.mkdir()
    (bad / "arrays_0.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5


def test_ckpt_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, _tree(1), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_ckpt_resume_bit_exact_training(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3 more."""
    from repro.launch.train import make_train_step, opt_init
    cfg = reduced(get_config("llama2-7b"))
    from repro.models import registry
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=6, warmup_steps=0)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False, dtype=jnp.float32))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_init(params)
    batches = list(token_batches(cfg, 2, 16, 6, seed=0))
    # straight run
    p1, o1 = params, opt
    for b in batches:
        p1, o1, _ = step(p1, o1, b)
    # interrupted run
    mgr = CheckpointManager(tmp_path, keep=2)
    p2, o2 = params, opt
    for b in batches[:3]:
        p2, o2, _ = step(p2, o2, b)
    mgr.save(2, (p2, o2))
    st, (p2, o2) = mgr.restore_latest((p2, o2))
    for b in batches[3:]:
        p2, o2, _ = step(p2, o2, b)
    diff = jax.tree.reduce(lambda a, b: max(a, b), jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2))
    assert diff < 1e-6


# --- data -----------------------------------------------------------------------

def test_markov_deterministic():
    a = markov_tokens(64, 100, seed=3)
    b = markov_tokens(64, 100, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 64


def test_markov_is_learnable_structure():
    """Bigram entropy of the Markov stream must be far below uniform."""
    toks = markov_tokens(32, 20_000, seed=0)
    joint = np.zeros((32, 32))
    for a, b in zip(toks[:-1], toks[1:]):
        joint[a, b] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -np.nansum(joint.sum(1) / joint.sum() *
                     np.nansum(np.where(cond > 0, cond * np.log2(cond), 0), axis=1))
    assert ent < 0.8 * np.log2(32)


def test_batches_resumable():
    cfg = reduced(get_config("llama2-7b"))
    b1 = list(token_batches(cfg, 2, 8, 4, seed=1))
    b2 = list(token_batches(cfg, 2, 8, 4, seed=1))
    np.testing.assert_array_equal(np.asarray(b1[3]["tokens"]),
                                  np.asarray(b2[3]["tokens"]))


def test_make_batch_families():
    for arch in ("qwen2-vl-7b", "whisper-large-v3", "mamba2-1.3b"):
        cfg = reduced(get_config(arch))
        b = make_batch(cfg, 2, 16, 0)
        assert "labels" in b
