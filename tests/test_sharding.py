"""Sharding rules + an 8-device pjit equivalence test (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import registry
from repro.parallel import sharding


class _FakeMesh:
    """Shape-only stand-in so spec rules can be tested without 256 devices."""
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    """Every sharded dim must divide evenly by its mesh axis size."""
    cfg = get_config(arch)
    sds = registry.param_shapes(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = sharding.param_specs(sds, mesh)

    def check(path, leaf):
        spec = None

    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(sds)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        for i, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[i] % size == 0, (arch, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["dbrx-132b", "olmoe-1b-7b"])
def test_moe_expert_parallel(arch):
    cfg = get_config(arch)
    sds = registry.param_shapes(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = sharding.param_specs(sds, mesh)
    moe_spec = specs["blocks"][0]["moe"]["w1"]
    assert moe_spec[1] == "model"   # expert dim (after repeat dim)
    assert all(p is None for i, p in enumerate(moe_spec) if i != 1)


def test_zero_sharding_adds_data_axis():
    cfg = get_config("llama2-7b")
    sds = registry.param_shapes(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = sharding.param_specs(sds, mesh)
    z = sharding.zero_shard_specs(specs, sds, mesh)
    before = sum("data" in str(s) for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    after = sum("data" in str(s) for s in jax.tree_util.tree_leaves(
        z, is_leaf=lambda x: isinstance(x, P)))
    assert after > before


def test_dp_size_is_host_int():
    """dp_size is used while *building* specs — it must be an exact host int
    (math.prod), never a device-array round-trip."""
    assert sharding.dp_size(_FakeMesh({"data": 16, "model": 16})) == 16
    assert sharding.dp_size(_FakeMesh({"pod": 2, "data": 16,
                                       "model": 16})) == 32
    assert type(sharding.dp_size(_FakeMesh({"model": 16}))) is int


def test_payload_specs_quant_aware():
    """With qmeta, payload leaves shard per their weight's TP mode: column
    weights shard packed n_words (side info replicated), row weights shard
    K / the group dim together."""
    from repro.core.quantized import quantized_param_shapes
    from repro.models import registry
    cfg = get_config("llama2-7b")
    sds = registry.param_shapes(cfg)
    qsds, qmeta = quantized_param_shapes(sds, bits=4, d=8)
    mesh = _FakeMesh({"data": 4, "model": 4})
    specs = sharding.param_specs(qsds, mesh, qmeta=qmeta)
    attn = specs["blocks"][0]["attn"]
    mlp = specs["blocks"][0]["mlp"]
    # column-parallel wq: packed [R, K, n_words] shards words; side info repl.
    assert attn["wq"]["packed"] == P(None, None, "model")
    assert attn["wq"]["g"] == P(None, None, None, None)
    assert attn["wq"]["mu"] == P(None, None)
    # row-parallel wo: packed shards K, g/mu/scale shard their group dim
    assert attn["wo"]["packed"] == P(None, "model", None)
    assert attn["wo"]["g"] == P(None, "model", None, None)
    assert attn["wo"]["mu"] == P(None, "model")
    assert attn["wo"]["scale"] == P(None, "model")
    # w2's K is the FFN dim (11008 -> 86 groups, not divisible by 4): the
    # whole payload must stay consistently replicated, not half-sharded
    assert mlp["w2"]["packed"] == P(None, None, None)
    assert mlp["w2"]["mu"] == P(None, None)
    # every sharded dim still divides evenly
    flat_s = jax.tree_util.tree_leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(qsds)
    for spec, leaf in zip(flat_s, flat_l):
        for i, part in enumerate(spec):
            if part is not None:
                assert leaf.shape[i] % mesh.shape["model"] == 0, \
                    (spec, leaf.shape)


def test_payload_specs_word_unit_alignment():
    """bits=3 (per_word=10): shards must land on whole-word / whole-vector
    boundaries, so an indivisible N stays replicated instead of padding."""
    from repro.core.quantized import QuantLinearMeta
    meta = QuantLinearMeta(k=256, n=320, bits=3, d=8, group_size=128)
    # unit = lcm(10, 8) = 40 codes = 4 words; tp=2 -> n % 80 == 0: ok
    s = sharding._payload_leaf_spec("wq", "packed", (256, 32), 2, meta)
    assert s == P(None, "model")
    # tp=16 -> n % 640 != 0: replicate (no GSPMD padding)
    s = sharding._payload_leaf_spec("wq", "packed", (256, 32), 16, meta)
    assert s == P(None, None)
    # row: n_groups=2 divides tp=2 but not tp=4
    assert sharding._payload_leaf_spec(
        "wo", "packed", (256, 32), 2, meta) == P("model", None)
    assert sharding._payload_leaf_spec(
        "wo", "packed", (256, 32), 4, meta) == P(None, None)
    assert sharding._payload_leaf_spec(
        "wo", "mu", (2,), 4, meta) == P(None)


@pytest.mark.parametrize("arch", ["llama2-7b", "recurrentgemma-9b"])
@pytest.mark.parametrize("kind", ["paged", "paged_q8"])
def test_cache_specs_paged_pools_never_shard_pool_dims(arch, kind):
    """Regression: kp/vp/ksc/vsc are [num_blocks, block_size, KV(, hd)] pool
    layouts, NOT dense [B, S, ...]; the old dense rules data-sharded
    block_size and the table's slots dim (desyncing it from the host-side
    SlotPages mirror)."""
    cfg = get_config(arch)
    sds = registry.cache_specs(cfg, 4, 64, jnp.float32, cache_kind=kind)
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    specs = sharding.cache_specs_tree(sds, mesh)

    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    flat_l = jax.tree_util.tree_leaves(sds)
    assert len(flat_s) == len(flat_l)
    seen = set()
    for (path, spec), leaf in zip(flat_s, flat_l):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if name in ("kp", "vp", "ksc", "vsc"):
            seen.add(name)
            # pool dims (num_blocks, block_size) and data axes: never sharded
            nd = leaf.ndim
            pool_dims = (nd - 4, nd - 3) if name in ("kp", "vp") \
                else (nd - 3, nd - 2)
            for i in pool_dims:
                assert spec[i] is None, (name, spec, leaf.shape)
            for part in spec:
                assert part not in ("data", "pod"), (name, spec)
                assert not (isinstance(part, tuple) and
                            ("data" in part or "pod" in part)), (name, spec)
            # KV head dim over model only when divisible
            kv = nd - 2 if name in ("kp", "vp") else nd - 1
            if leaf.shape[kv] % mesh.shape["model"] == 0:
                assert spec[kv] == "model", (name, spec, leaf.shape)
        elif name == "table":
            seen.add(name)
            assert spec == P(None, None)
    assert {"kp", "vp", "table"} <= seen
    if kind == "paged_q8":
        assert {"ksc", "vsc"} <= seen


def test_batch_specs_replicate_indivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    b = dict(tokens=jax.ShapeDtypeStruct((1, 128), jnp.int32))
    specs = sharding.batch_specs(b, mesh)
    assert specs["tokens"] == P(None, None)
    b2 = dict(tokens=jax.ShapeDtypeStruct((32, 128), jnp.int32))
    assert sharding.batch_specs(b2, mesh)["tokens"] == P("data", None)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    import numpy as np
    sys.path.insert(0, "src")
    from repro.configs import get_config, reduced
    from repro.models import registry
    from repro.optim import AdamWConfig
    from repro.launch.train import make_train_step, opt_init, shardings_for_train
    from repro.parallel import sharding
    from repro.data.synthetic import make_batch

    cfg = reduced(get_config("llama2-7b"))
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=4, warmup_steps=0)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_init(params)
    batch = make_batch(cfg, 4, 16, 0)
    step = make_train_step(cfg, opt_cfg, remat=False, dtype=jnp.float32)

    # single-device reference
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    in_sh, out_sh = shardings_for_train(cfg, mesh, params, batch, zero=True)
    jstep = jax.jit(step, in_shardings=sharding.named(in_sh, mesh),
                    out_shardings=sharding.named(out_sh, mesh))
    with mesh:
        p2, o2, m2 = jstep(params, opt, batch)
    diff = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2))
    print(json.dumps(dict(loss1=float(m1["loss"]), loss2=float(m2["loss"]),
                          diff=diff)))
""")


def test_pjit_train_step_matches_single_device():
    """The sharded train step must be numerically identical to 1-device."""
    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss1"] - res["loss2"]) < 1e-4
    assert res["diff"] < 1e-4


def test_elastic_plan():
    from repro.ckpt.elastic import plan_elastic
    mesh = _FakeMesh({"data": 16, "model": 16})
    mesh.devices = np.zeros(256)
    plan = plan_elastic(256, mesh)
    assert plan.per_replica_batch * 16 * plan.accum_steps == 256
    plan2 = plan_elastic(100, mesh)   # not divisible by 16
    assert plan2.per_replica_batch * 16 * plan2.accum_steps >= 100


_COMPRESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, sys
    import jax, jax.numpy as jnp
    import numpy as np
    sys.path.insert(0, "src")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.optim.compression import compressed_pod_psum

    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 16)).astype("float32"))
    # place pod-sharded replicas: simulate per-pod partial grads by splitting
    gp = jax.device_put(g, NamedSharding(mesh, P()))
    with mesh:
        out = jax.jit(lambda t: compressed_pod_psum(dict(w=t), mesh))(gp)
    ref = 2 * g  # two pods each contribute g
    err = float(jnp.max(jnp.abs(out["w"] - ref)) / (jnp.max(jnp.abs(ref))))
    print(json.dumps(dict(err=err)))
""")


def test_compressed_pod_psum_subprocess():
    """int8-EF all-gather reduce over the pod axis sums correctly (4 dev)."""
    from repro.optim.compression import shard_map_fn
    if shard_map_fn() is None:
        pytest.skip("no shard_map in this jax build (needs jax.shard_map or "
                    "jax.experimental.shard_map); multi-device psum "
                    "cannot run")
    out = subprocess.run([sys.executable, "-c", _COMPRESS_SCRIPT],
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=dict(os.environ), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 0.02   # int8 quantization tolerance
