"""Serving telemetry subsystem: registry/histogram units, trace hooks, and
the instrumented engine against hand-computed oracles.

The engine-level tests use EXACT oracles wherever the clock allows it: the
request lifecycle timestamps (``t_submit`` / ``t_first_sched`` /
``t_first_token``) are the same floats the histograms observed, so sums
match bit-for-bit; slab valid/pad token totals come from the analytic
packing identity (each request consumes ``len(prompt) + generated - 1``
valid positions) rather than re-reading the scheduler's own counters.
"""
import io
import json
import urllib.error
import urllib.request

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serving import (ContinuousBatcher, EngineConfig, FCFSPolicy,
                           Request, SamplingParams, ServingEngine,
                           TokenBudgetPolicy, kvcache)
from repro.serving import metrics as M
from repro.serving import trace as T

S_CACHE, BLOCK, CHUNK = 32, 4, 5


def _params(arch="llama2-7b", seed=0):
    cfg = reduced(get_config(arch))
    return cfg, registry.init_params(jax.random.PRNGKey(seed), cfg)


def _ecfg(**kw):
    kw.setdefault("dtype", jnp.float32)
    kw.setdefault("s_cache", S_CACHE)
    kw.setdefault("slots", 2)
    kw.setdefault("block_size", BLOCK)
    return EngineConfig(**kw)


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_log_buckets_cover_range_log_spaced():
    b = M.log_buckets(1e-3, 10.0, 3)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] >= 10.0
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** (1 / 3), rel=1e-9) for r in ratios)
    with pytest.raises(ValueError):
        M.log_buckets(1.0, 0.5)


def test_counter_inc_and_cumulative_mirror():
    c = M.Counter()
    c.inc()
    c.inc(2.5)
    assert c.snapshot() == 3.5
    c.set_cumulative(10)
    assert c.snapshot() == 10
    c.set_cumulative(4)                   # external totals never move it back
    assert c.snapshot() == 10


def test_gauge_tracks_high_water():
    g = M.Gauge()
    g.set(3)
    g.set(7)
    g.set(2)
    assert g.snapshot() == 2
    assert g.high_water == 7


def test_histogram_counts_sum_minmax_and_percentiles():
    h = M.Histogram(buckets=(1.0, 10.0, 100.0))
    assert h.percentile(50) is None       # empty
    for v in (0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(56.0)
    assert (h.min, h.max) == (0.5, 50.0)
    snap = h.snapshot()
    assert snap["buckets"] == {"1.0": 2, "10.0": 1, "100.0": 1, "+Inf": 0}
    assert snap["mean"] == pytest.approx(14.0)
    # p50 falls in the first bucket; interpolation stays within its bounds
    # (clamped to the observed min), p99 clamps to the observed max
    assert h.min <= snap["p50"] <= 1.0
    assert snap["p99"] == 50.0
    # one-sample histogram reports that sample at every percentile
    h1 = M.Histogram(buckets=(1.0,))
    h1.observe(0.25)
    assert h1.percentile(50) == 0.25 and h1.percentile(99) == 0.25


def test_registry_get_or_create_labels_and_kind_collision():
    mx = M.MetricsRegistry()
    a = mx.counter("reqs", "help text", reason="length")
    b = mx.counter("reqs", reason="length")
    assert a is b                          # idempotent per (name, labels)
    mx.counter("reqs", reason="stop_token").inc(2)
    a.inc()
    with pytest.raises(ValueError, match="counter"):
        mx.gauge("reqs")
    snap = mx.snapshot()
    assert snap["counters"]["reqs"] == {"reason=length": 1.0,
                                        "reason=stop_token": 2.0}


def test_prometheus_rendering_format():
    mx = M.MetricsRegistry()
    mx.counter("events_total", "things that happened").inc(3)
    mx.gauge("depth", kind="q").set(2)
    h = mx.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = mx.render_prometheus()
    assert "# HELP events_total things that happened" in text
    assert "# TYPE events_total counter" in text
    assert "events_total 3" in text
    assert 'depth{kind="q"} 2' in text
    # histogram buckets are CUMULATIVE counts, closed by +Inf == _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    assert "lat_seconds_sum 5.55" in text


def test_timer_laps_and_histogram_context():
    h = M.Histogram(buckets=(10.0,))
    with M.Timer(h) as tm:
        pass
    assert tm.elapsed >= 0 and h.count == 1
    t2 = M.Timer()
    a = t2.lap()
    b = t2.lap()
    assert a >= 0 and b >= 0 and t2.total >= a + b


def test_log_event_format(capsys):
    M.log_event("tag", step=3, loss=0.1234567, note="hi")
    out = capsys.readouterr().out
    assert out.startswith("[tag] ")
    assert "step=3" in out and "loss=0.1235" in out and "note=hi" in out


def test_trace_log_jsonl_roundtrip():
    buf = io.StringIO()
    with T.TraceLog(buf) as tl:
        tl.write(dict(kind="iteration", width=4))
        tl.write(dict(kind="iteration", width=1))
    assert tl.records == 2
    recs = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert [r["width"] for r in recs] == [4, 1]
    assert all("ts" in r for r in recs)


def test_trace_annotate_is_nullcontext_when_disabled():
    T.enable(False)
    try:
        import contextlib
        assert isinstance(T.annotate("x"), contextlib.nullcontext)
        assert isinstance(T.host_span("x"), contextlib.nullcontext)
        T.enable(True)
        with T.annotate("named"):      # jax.named_scope outside a trace: ok
            pass
    finally:
        T.enable(False)


# ---------------------------------------------------------------------------
# BlockAllocator telemetry
# ---------------------------------------------------------------------------

def test_block_allocator_telemetry_counters():
    al = kvcache.BlockAllocator(num_blocks=4)       # usable ids: 1, 2, 3
    a, b = al.alloc(), al.alloc()
    assert (al.total_allocs, al.high_water) == (2, 2)
    al.free([a])
    assert al.total_frees == 1 and al.used_blocks == 1
    c, d = al.alloc(), al.alloc()
    assert al.high_water == 3                       # new peak
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()
    assert al.pool_exhausted == 1
    al.free([b, c, d])
    assert al.high_water == 3                       # peak survives the frees
    assert al.total_allocs == 4 and al.total_frees == 4


def test_block_allocator_double_free_counts_and_raises():
    al = kvcache.BlockAllocator(num_blocks=4)
    a = al.alloc()
    al.free([a])
    with pytest.raises(RuntimeError, match="double free"):
        al.free([a])
    assert al.double_free_rejected == 1
    # batch validation: nothing from the bad batch was released
    b = al.alloc()
    with pytest.raises(RuntimeError, match="double free"):
        al.free([b, b])
    assert al.double_free_rejected == 2
    assert al.used_blocks == 1                      # b still live
    al.free([b])                                    # clean free still works
    assert al.used_blocks == 0


# ---------------------------------------------------------------------------
# instrumented engine vs hand-computed oracles
# ---------------------------------------------------------------------------

def test_ttft_and_queue_wait_match_request_timestamps():
    """2-request greedy run: the TTFT / queue-wait histograms must hold
    exactly the per-request timestamp deltas (same floats, so the sums
    match bit-for-bit), bracketed by our own wall clock."""
    cfg, params = _params()
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=CHUNK))
    tm = M.Timer()
    hs = [eng.submit([1, 2, 3, 4, 5, 6], SamplingParams(max_tokens=3)),
          eng.submit([7, 8, 9], SamplingParams(max_tokens=3))]
    eng.run()
    wall = tm.total
    reqs = [h.request for h in hs]
    assert all(r.t_submit <= r.t_first_sched <= r.t_first_token
               for r in reqs)
    snap = eng.metrics_snapshot()
    ttft = snap["histograms"]["serving_ttft_seconds"][""]
    qw = snap["histograms"]["serving_queue_wait_seconds"][""]
    assert ttft["count"] == 2 and qw["count"] == 2
    assert ttft["sum"] == sum(r.t_first_token - r.t_submit for r in reqs)
    assert qw["sum"] == sum(r.t_first_sched - r.t_submit for r in reqs)
    assert 0 < ttft["max"] <= wall and 0 <= qw["max"] <= ttft["max"]
    # inter-token: each request emits 3 tokens -> 2 gaps each
    itl = snap["histograms"]["serving_inter_token_seconds"][""]
    assert itl["count"] == 4
    assert snap["counters"]["serving_tokens_generated_total"][""] == 6
    assert snap["counters"]["serving_requests_submitted_total"][""] == 2


def test_done_reason_counters_match_handles():
    """One request per retirement path — length / stop_token / cache_full —
    and the ``serving_requests_finished_total{reason=}`` counters must
    mirror the handles' ``done_reason``."""
    cfg, params = _params()
    rng = np.random.default_rng(5)
    prompt = list(map(int, rng.integers(1, cfg.vocab, 4)))
    # discover what greedy generates so a stop token is guaranteed to land
    probe = ServingEngine(params, cfg, _ecfg(chunk_size=CHUNK))
    toks = probe.generate(prompt, SamplingParams(max_tokens=4)).tokens
    stop = toks[2]

    eng = ServingEngine(params, cfg, _ecfg(chunk_size=CHUNK, slots=3))
    hs = [
        eng.submit(prompt, SamplingParams(max_tokens=2)),          # length
        eng.submit(prompt, SamplingParams(max_tokens=8,
                                          stop_token_ids=(stop,))),
        eng.submit(prompt, SamplingParams(max_tokens=None)),       # cache
    ]
    eng.run()
    reasons = [h.done_reason for h in hs]
    assert reasons == ["length", "stop_token", "cache_full"]
    got = eng.metrics_snapshot()["counters"][
        "serving_requests_finished_total"]
    want = {}
    for r in reasons:
        want[f"reason={r}"] = want.get(f"reason={r}", 0) + 1.0
    assert got == want


class _WidthRecorder:
    """Record every slab width the scheduler actually ran."""

    def __init__(self, inner):
        self.inner = inner
        self.plans = []                   # one width per engine iteration

    @property
    def name(self):
        return self.inner.name            # the width-label the metrics use

    def assign(self, slots, queue):
        return self.inner.assign(slots, queue)

    def widths(self, remaining, chunk):
        t, takes = self.inner.widths(remaining, chunk)
        self.plans.append(t)
        return t, takes

    def program_widths(self, chunk):
        return self.inner.program_widths(chunk)


@pytest.mark.parametrize("make_policy", [FCFSPolicy,
                                         lambda: TokenBudgetPolicy(6)])
def test_slab_padding_counters_match_packing_oracle(make_policy):
    """Valid-token totals follow the analytic identity (each request
    consumes ``len(prompt) + generated - 1`` valid slab positions); pad is
    the recorded per-iteration ``slots * width`` minus that.  Holds for
    both packers — only the split between valid and pad moves."""
    cfg, params = _params(seed=1)
    rec = _WidthRecorder(make_policy())
    cb = ContinuousBatcher(params, cfg, _ecfg(chunk_size=CHUNK), policy=rec)
    rng = np.random.default_rng(3)
    plens = (9, 3, 6)
    max_new = 3
    for i, n in enumerate(plens):
        cb.submit(Request(rid=i, prompt=list(
            map(int, rng.integers(1, cfg.vocab, n))), max_new=max_new))
    done = cb.run()
    gen = sum(len(r.tokens) for r in done.values())
    valid_oracle = sum(plens) + gen - len(plens)
    slab_oracle = len(cb.slots) * sum(rec.plans)
    snap = cb.metrics.snapshot()
    slab = snap["counters"]["serving_slab_tokens_total"]
    assert slab["kind=valid"] == valid_oracle
    assert slab["kind=pad"] == slab_oracle - valid_oracle
    # per-rung iteration counters partition the iterations exactly
    iters = snap["counters"]["serving_iterations_total"]
    name = rec.inner.name
    for w in set(rec.plans):
        assert iters[f"policy={name},width={w}"] == rec.plans.count(w)
    assert sum(iters.values()) == len(rec.plans)


def _spy_compiled_widths(monkeypatch):
    real = registry.chunk_step
    widths = []

    def spy(params, cache, tokens, pos, lens, cfg, **kw):
        widths.append(tokens.shape[1])
        return real(params, cache, tokens, pos, lens, cfg, **kw)

    monkeypatch.setattr(registry, "chunk_step", spy)
    return widths


def test_compile_event_counter_matches_trace_spy(monkeypatch):
    cfg, params = _params()
    widths = _spy_compiled_widths(monkeypatch)
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=CHUNK))
    eng.submit([1, 2, 3, 4, 5, 6, 7], SamplingParams(max_tokens=3))
    eng.run()
    compiles = eng.metrics_snapshot()["counters"][
        "serving_compile_events_total"][""]
    assert compiles == len(widths) > 0    # one hook hit per traced program


def test_metrics_off_is_noop_same_compiled_programs(monkeypatch):
    """EngineConfig(metrics=False) must leave the jitted step untouched:
    the chunk_step spy sees the same program family, and nothing is ever
    recorded into the registry."""
    cfg, params = _params(seed=1)

    def run(metrics_on):
        widths = _spy_compiled_widths(monkeypatch)
        eng = ServingEngine(params, cfg,
                            _ecfg(chunk_size=CHUNK, metrics=metrics_on))
        for i, n in enumerate((6, 3)):
            eng.submit(list(range(1, n + 1)), SamplingParams(max_tokens=3),
                       rid=i)
        done = eng.run()
        toks = {i: r.tokens for i, r in done.items()}
        monkeypatch.undo()
        return widths, toks, eng.metrics_snapshot()

    w_on, toks_on, snap_on = run(True)
    w_off, toks_off, snap_off = run(False)
    assert w_on == w_off                  # identical compiled-call pattern
    assert toks_on == toks_off            # identical outputs
    assert snap_off == dict(counters={}, gauges={}, histograms={})
    assert snap_on["counters"]["serving_tokens_generated_total"][""] == 6


def test_paged_run_block_pool_gauges_and_prometheus():
    cfg, params = _params()
    eng = ServingEngine(params, cfg,
                        _ecfg(chunk_size=CHUNK, cache_kind="paged"))
    eng.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=3))
    eng.run()
    snap = eng.metrics_snapshot()
    al = eng.batcher.pages.alloc
    assert snap["gauges"]["kv_blocks_used"][""] == al.used_blocks == 0
    assert snap["gauges"]["kv_blocks_used__high_water"][""] \
        == al.high_water > 0
    assert snap["gauges"]["kv_blocks_high_water"][""] == al.high_water
    assert snap["counters"]["kv_block_allocs_total"][""] == al.total_allocs
    assert snap["counters"]["kv_block_frees_total"][""] == al.total_frees
    assert al.total_allocs == al.total_frees > 0
    # live-slot resident bytes went up then back to 0 at retirement
    res = snap["gauges"]["kv_cache_resident_bytes"]["kind=paged"]
    hw = snap["gauges"]["kv_cache_resident_bytes__high_water"]["kind=paged"]
    assert res == 0 and hw > 0
    text = eng.render_prometheus()
    assert "kv_blocks_used 0" in text
    assert 'serving_ttft_seconds_bucket{le="+Inf"} 1' in text
    assert "serving_requests_finished_total" in text


def test_kv_byte_economy_gauges_and_host_label():
    """serving_kv_bytes_per_token{kind=,host=} tracks the analytic model
    while slots are live (high-water > 0, back to 0 at retirement) and
    serving_kv_codebook_bytes{host=} is the flat GLVQ codebook overhead —
    positive only for paged_glvq, present in snapshot AND Prometheus."""
    import jax
    from repro.serving import kvcache as skv
    cfg, params = _params()
    host = f"host={jax.process_index()}"
    for kind, book_positive in (("paged_glvq", True), ("paged_q8", False)):
        eng = ServingEngine(params, cfg,
                            _ecfg(chunk_size=CHUNK, cache_kind=kind))
        eng.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=3))
        eng.run()
        snap = eng.metrics_snapshot()
        bpt = snap["gauges"]["serving_kv_bytes_per_token"]
        key = f"{host},kind={kind}"
        assert bpt[key] == 0.0                       # all slots retired
        hw = snap["gauges"]["serving_kv_bytes_per_token__high_water"][key]
        assert hw > 0
        book = snap["gauges"]["serving_kv_codebook_bytes"][host]
        want_book = skv.codebook_bytes(cfg, kind)
        assert book == want_book
        assert (book > 0) == book_positive
        text = eng.render_prometheus()
        assert "serving_kv_bytes_per_token{" in text
        assert "serving_kv_codebook_bytes{" in text
    # glvq stores fewer bytes per live token than int8 at equal positions
    assert skv.bytes_per_token(cfg, "paged_glvq", 8, 32, 4) < \
        skv.bytes_per_token(cfg, "paged_q8", 8, 32, 4)


def test_trace_log_iteration_records_from_engine(tmp_path):
    path = tmp_path / "trace.jsonl"
    cfg, params = _params()
    eng = ServingEngine(params, cfg, _ecfg(chunk_size=CHUNK),
                        trace_log=str(path))
    eng.submit([1, 2, 3, 4], SamplingParams(max_tokens=2))
    eng.run()
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert recs and all(r["kind"] == "iteration" for r in recs)
    assert [r["iter"] for r in recs] == list(range(1, len(recs) + 1))
    assert all(r["slots"] == 2 and r["step_s"] > 0 for r in recs)
    emitted = [e for r in recs for e in r["events"]]
    assert len(emitted) == 2 and emitted[-1]["done"]
    assert emitted[-1]["done_reason"] == "length"


def test_http_exporter_serves_prometheus_and_json():
    mx = M.MetricsRegistry()
    mx.counter("up_total", "liveness").inc()
    server = M.serve_http(mx, port=0)
    try:
        port = server.server_address[1]
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "up_total 1" in text
        snap = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json", timeout=5).read())
        assert snap["counters"]["up_total"][""] == 1.0
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope",
                                   timeout=5)
    finally:
        server.shutdown()
