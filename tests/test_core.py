"""Companding, packing, SDBA, GLVQ loop, baselines — the paper core."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import GLVQConfig, companding, packing, quantize_layer, \
    dequantize_layer, sdba as sdba_mod
from repro.core.baselines import (e8_basis, gptq_quantize, rtn_quantize)
from repro.core.sdba import allocate_bits, fractional_bits, group_salience


# --- companding -------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(10.0, 255.0), st.integers(0, 10_000))
def test_companding_inverse(mu, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, size=128), jnp.float32)
    y = companding.compand(x, mu)
    xr = companding.expand(y, mu)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=2e-5)
    assert float(jnp.max(jnp.abs(y))) <= 1.0 + 1e-6


def test_companding_expands_small_values():
    mu = 100.0
    x = jnp.asarray([0.01, 0.5])
    y = companding.compand(x, mu)
    assert float(y[0]) / 0.01 > float(y[1]) / 0.5  # more resolution near 0


def test_mu_init_range():
    rng = np.random.default_rng(0)
    heavy = jnp.asarray(rng.standard_t(2, size=4096), jnp.float32)
    light = jnp.asarray(rng.uniform(-1, 1, size=4096), jnp.float32)
    mu_h = companding.init_mu(heavy)
    mu_l = companding.init_mu(light)
    assert companding.MU_MIN <= float(mu_l) <= float(mu_h) <= companding.MU_MAX


# --- packing ----------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 8), st.integers(1, 97), st.integers(0, 10_000))
def test_pack_roundtrip(bits, n, seed):
    rng = np.random.default_rng(seed)
    lo = -(2 ** (bits - 1)) if bits > 1 else -1
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 0
    codes = jnp.asarray(rng.integers(lo, hi + 1, size=(3, n)), jnp.int32)
    packed = packing.pack_codes(codes, bits)
    out = packing.unpack_codes(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_packing_density():
    # 4-bit: exactly 8 codes per word
    assert packing.packed_len(1024, 4) == 128
    assert packing.packed_len(1024, 2) == 64
    assert packing.packed_len(10, 3) == 1 and packing.packed_len(11, 3) == 2


# --- SDBA ---------------------------------------------------------------------

def test_sdba_constraints():
    rng = np.random.default_rng(0)
    s = rng.lognormal(0, 2.0, size=64)
    v = rng.uniform(0.5, 2.0, size=64)
    for n in (2, 3, 4):
        bits = allocate_bits(s, v, n)
        assert bits.mean() == n                       # exact rate
        assert (bits == n + 1).sum() == (bits == n - 1).sum()  # balanced
        assert set(np.unique(bits)) <= {n - 1, n, n + 1}


def test_sdba_salience_ordering():
    s = np.array([100.0, 1.0, 1.0, 0.001])
    v = np.ones(4)
    bits = allocate_bits(s, v, 2)
    assert bits[0] == 3 and bits[3] == 1


def test_fractional_bits_rate():
    rng = np.random.default_rng(1)
    s, v = rng.uniform(size=32), rng.uniform(size=32)
    bits = fractional_bits(s, v, 1.5)
    assert abs(bits.mean() - 1.5) < 1e-9


def test_salience_uses_hessian_diag():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(256, 16)), jnp.float32)
    h = jnp.diag(jnp.concatenate([jnp.full((128,), 100.0), jnp.ones((128,))]))
    s = group_salience(w, h, 128)
    assert float(s[0]) > float(s[1])


# --- GLVQ loop -----------------------------------------------------------------

def _setup(seed=0, k=128, n=32, nx=256):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_t(3, size=(k, n)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, nx)), jnp.float32)
    return w, x @ x.T


def _obj(w, w_hat, h):
    d = w - w_hat
    return float(jnp.sum((h @ d) * d))


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_glvq_beats_rtn_and_gptq(bits):
    w, h = _setup()
    cfg = GLVQConfig(d=8, bits=bits, iters=40)
    q = quantize_layer(w, h, cfg)
    glvq_obj = _obj(w, dequantize_layer(q, cfg), h)
    rtn_obj = _obj(w, rtn_quantize(w, bits), h)
    gptq_obj = _obj(w, gptq_quantize(w, h, bits), h)
    assert glvq_obj < rtn_obj
    assert glvq_obj < gptq_obj * 1.05   # usually strictly better


def test_glvq_learned_beats_fixed_lattice():
    w, h = _setup(seed=3)
    cfg = GLVQConfig(d=8, bits=2, iters=40)
    fixed = dataclasses.replace(cfg, learn_lattice=False)
    lobj = _obj(w, dequantize_layer(quantize_layer(w, h, cfg), cfg), h)
    fobj = _obj(w, dequantize_layer(quantize_layer(w, h, fixed), fixed), h)
    assert lobj <= fobj * 1.02


def test_glvq_companding_helps_heavy_tails():
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_t(2, size=(128, 32)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    h = x @ x.T
    cfg = GLVQConfig(d=8, bits=2, iters=40)
    off = dataclasses.replace(cfg, use_companding=False)
    on_obj = _obj(w, dequantize_layer(quantize_layer(w, h, cfg), cfg), h)
    off_obj = _obj(w, dequantize_layer(quantize_layer(w, h, off), off), h)
    assert on_obj <= off_obj * 1.05


def test_gcd_is_a_refinement_of_babai():
    """Our GCD starts from Babai and greedily descends ||y - Gz||, so its
    y-space error can never exceed Babai's for the same basis. (The paper's
    Table 12 claim — Babai better END-TO-END — is exercised at the model
    level in benchmarks/table12, where the alternating loop interacts with
    the index assignment.)"""
    from repro.core.glvq import _round_codes, _to_vectors
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_t(3, size=(8, 512)) * 0.5, jnp.float32)
    g = jnp.asarray(rng.normal(size=(8, 8)) * 0.1 + np.eye(8) * 0.3,
                    jnp.float32)
    cfg_b = GLVQConfig(d=8, bits=3, rounding="babai")
    cfg_g = GLVQConfig(d=8, bits=3, rounding="gcd", gcd_sweeps=2)
    zb = _round_codes(g, w, jnp.asarray(3), cfg_b)
    zg = _round_codes(g, w, jnp.asarray(3), cfg_g)
    eb = float(jnp.sum((w - g @ zb) ** 2))
    eg = float(jnp.sum((w - g @ zg) ** 2))
    assert eg <= eb + 1e-5
    # and GCD respects the clip range
    assert float(zg.min()) >= -4 and float(zg.max()) <= 3


def test_glvq_mixed_bits_respects_codes():
    w, h = _setup(seed=6, k=256)
    cfg = GLVQConfig(d=8, bits=2, iters=10)
    bits = jnp.asarray([1, 3], jnp.int32)
    q = quantize_layer(w, h, cfg, bits)
    c0 = np.asarray(q["codes"][0])
    c1 = np.asarray(q["codes"][1])
    assert c0.min() >= -1 and c0.max() <= 0
    assert c1.min() >= -4 and c1.max() <= 3


def test_glvq_bits_budget_vs_error_monotone():
    w, h = _setup(seed=7)
    objs = []
    for bits in (2, 3, 4):
        cfg = GLVQConfig(d=8, bits=bits, iters=30)
        objs.append(_obj(w, dequantize_layer(quantize_layer(w, h, cfg), cfg), h))
    assert objs[0] > objs[1] > objs[2]


# --- baselines ----------------------------------------------------------------

def test_gptq_beats_rtn_on_correlated_inputs():
    rng = np.random.default_rng(8)
    k, n = 128, 16
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
    base = rng.normal(size=(k, 8))
    x = jnp.asarray(base @ rng.normal(size=(8, 512)) + 0.1 * rng.normal(size=(k, 512)),
                    jnp.float32)
    h = x @ x.T
    assert _obj(w, gptq_quantize(w, h, 3), h) < _obj(w, rtn_quantize(w, 3), h)


def test_e8_basis_full_rank():
    g = e8_basis()
    assert abs(np.linalg.det(g)) > 1e-6
