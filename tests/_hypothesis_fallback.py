"""Property-test shim: use hypothesis when installed, else a tiny
deterministic sampler so the suite still collects and runs.

The fallback implements just the surface these tests use — ``@given`` with
positional strategies, ``@settings(max_examples=..., deadline=...)``, and the
``floats`` / ``integers`` / ``sampled_from`` strategies — drawing samples
from a fixed-seed numpy generator so failures reproduce.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:

    import types

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _floats(lo, hi):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = types.SimpleNamespace(floats=_floats, integers=_integers,
                               sampled_from=_sampled_from)

    def given(*strats):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake the sampled
            # parameters for fixtures (hypothesis strips them the same way)
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 20)):
                    fn(*(s.sample(rng) for s in strats))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = 20
            return wrapper
        return deco

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn
        return deco
