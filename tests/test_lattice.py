"""Lattice primitives: Babai rounding, error bound (Appendix A), LLL, init."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import lattice


def _rand_basis(rng, d, cond=3.0):
    a = rng.normal(size=(d, d))
    u, s, vt = np.linalg.svd(a)
    s = np.linspace(1.0, cond, d)
    return u @ np.diag(s) @ vt


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
def test_babai_error_bound_holds(seed, d):
    """Appendix A: ||x - G z|| <= bound(G) for UNCLIPPED Babai rounding."""
    rng = np.random.default_rng(seed)
    g = _rand_basis(rng, d)
    x = rng.normal(size=(d, 16)) * 3.0
    ginv = np.linalg.inv(g)
    z = np.round(ginv @ x)                      # no clipping
    err = np.linalg.norm(x - g @ z, axis=0)
    bound = lattice.babai_error_bound(g)
    assert np.all(err <= bound + 1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
def test_babai_exact_on_lattice_points(seed, d):
    """Lattice points round-trip exactly through encode/decode."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(_rand_basis(rng, d), jnp.float32)
    z_true = jnp.asarray(rng.integers(-3, 4, size=(d, 32)), jnp.float32)
    x = lattice.babai_decode(g, z_true)
    z = lattice.babai_round(jnp.linalg.inv(g), x, bits=4)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_true), atol=1e-3)


def test_babai_clipping_range():
    g = jnp.eye(4)
    x = jnp.full((4, 3), 100.0)
    for bits in (1, 2, 3, 4):
        z = lattice.babai_round(g, x, bits)
        lo, hi = lattice.int_range(bits)
        assert int(z.max()) <= hi and int(z.min()) >= lo


def test_lll_tightens_babai_bound():
    rng = np.random.default_rng(0)
    # deliberately skewed basis
    g = np.eye(4) + np.triu(rng.normal(size=(4, 4)) * 2.0, 1)
    before = lattice.babai_error_bound(g)
    after = lattice.babai_error_bound(lattice.lll_reduce(g))
    assert after <= before * 1.0 + 1e-9


def test_init_generation_matrix_coverage():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_t(3, size=(8, 4096)), jnp.float32)
    g0 = lattice.init_generation_matrix(v, bits=4)
    coords = jnp.linalg.inv(g0) @ v
    frac_in = float(jnp.mean(jnp.abs(coords) <= 8.0))
    assert frac_in > 0.95   # most coords land inside the 4-bit range


def test_spectral_clip():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    gc = lattice.spectral_clip(g, 0.5, 1.5)
    s = jnp.linalg.svd(gc, compute_uv=False)
    assert float(s.max()) <= 1.5 + 1e-4 and float(s.min()) >= 0.5 - 1e-4


# ---------------------------------------------------------------------------
# babai_round / babai_decode as the paged_glvq KV codec (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4]),
       st.sampled_from([3, 4]))
def test_babai_kv_codec_roundtrip_error_bound(seed, d, bits):
    """KV-codec property: per-token max-abs-normalized vectors encoded with
    babai_round against a well-conditioned G and decoded with babai_decode
    stay within the Appendix-A Babai bound whenever no coordinate clipped,
    and codes always lie in the signed bits-range (word-packable)."""
    rng = np.random.default_rng(seed)
    g = _rand_basis(rng, d, cond=2.0)
    # per-token normalized sub-vectors, scaled into the lattice's coverage
    x = rng.normal(size=(d, 64))
    x = x / np.maximum(np.abs(x).max(axis=0, keepdims=True), 1e-6)
    lo, hi = lattice.int_range(bits)
    g = g / np.abs(np.linalg.inv(g) @ x).max() * hi / (hi + 1)  # cover range
    ginv = jnp.asarray(np.linalg.inv(g), jnp.float32)
    z = lattice.babai_round(ginv, jnp.asarray(x, jnp.float32), bits)
    zn = np.asarray(z)
    assert zn.min() >= lo and zn.max() <= hi
    back = np.asarray(lattice.babai_decode(jnp.asarray(g, jnp.float32), z))
    unclipped = np.all((zn > lo) & (zn < hi), axis=0)
    err = np.linalg.norm(x - back, axis=0)
    bound = lattice.babai_error_bound(np.asarray(g, np.float32))
    assert np.all(err[unclipped] <= bound + 1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4]),
       st.sampled_from([3, 4]))
def test_babai_kv_codec_spectral_clip_ill_conditioned(seed, d, bits):
    """Ill-conditioned G edge: spectral_clip must bound the decode error
    amplification — after clipping to [0.25 s_max, s_max] the codec's
    roundtrip error on in-range data stays finite and within the clipped
    basis' Babai bound (an unclipped near-singular G would explode it)."""
    rng = np.random.default_rng(seed)
    u, _, vt = np.linalg.svd(rng.normal(size=(d, d)))
    s = np.linspace(1.0, 1e-6, d)                      # nearly singular
    g_bad = jnp.asarray(u @ np.diag(s) @ vt, jnp.float32)
    g = lattice.spectral_clip(g_bad, 0.25, 1.0)
    sv = np.linalg.svd(np.asarray(g), compute_uv=False)
    assert sv.min() >= 0.25 - 1e-4
    x = rng.normal(size=(d, 32)).astype(np.float32)
    x /= np.maximum(np.abs(x).max(axis=0, keepdims=True), 1e-6)
    x *= 0.2                                           # stay in coverage
    ginv = jnp.linalg.inv(g)
    z = lattice.babai_round(ginv, jnp.asarray(x), bits)
    back = np.asarray(lattice.babai_decode(g, z))
    err = np.linalg.norm(x - back, axis=0)
    assert np.all(np.isfinite(err))
    assert np.all(err <= lattice.babai_error_bound(np.asarray(g)) + 1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([3, 4]),
       st.sampled_from([10, 12, 16, 20]))
def test_babai_codes_word_pack_roundtrip_nondivisible(seed, bits, hd):
    """Word-packing edge: signed Babai codes at a head dim that does NOT
    fill the last uint32 word (hd % per_word != 0) must unpack bit-exactly
    — pad lanes are ignored, sign bits survive the word boundary."""
    from repro.core import packing
    rng = np.random.default_rng(seed)
    lo, hi = lattice.int_range(bits)
    codes = jnp.asarray(rng.integers(lo, hi + 1, size=(6, hd)), jnp.int32)
    words = packing.pack_codes(codes, bits)
    assert words.shape[-1] == packing.packed_len(hd, bits)
    back = packing.unpack_codes(words, bits, hd)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
