"""Lattice primitives: Babai rounding, error bound (Appendix A), LLL, init."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import lattice


def _rand_basis(rng, d, cond=3.0):
    a = rng.normal(size=(d, d))
    u, s, vt = np.linalg.svd(a)
    s = np.linspace(1.0, cond, d)
    return u @ np.diag(s) @ vt


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
def test_babai_error_bound_holds(seed, d):
    """Appendix A: ||x - G z|| <= bound(G) for UNCLIPPED Babai rounding."""
    rng = np.random.default_rng(seed)
    g = _rand_basis(rng, d)
    x = rng.normal(size=(d, 16)) * 3.0
    ginv = np.linalg.inv(g)
    z = np.round(ginv @ x)                      # no clipping
    err = np.linalg.norm(x - g @ z, axis=0)
    bound = lattice.babai_error_bound(g)
    assert np.all(err <= bound + 1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]))
def test_babai_exact_on_lattice_points(seed, d):
    """Lattice points round-trip exactly through encode/decode."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(_rand_basis(rng, d), jnp.float32)
    z_true = jnp.asarray(rng.integers(-3, 4, size=(d, 32)), jnp.float32)
    x = lattice.babai_decode(g, z_true)
    z = lattice.babai_round(jnp.linalg.inv(g), x, bits=4)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_true), atol=1e-3)


def test_babai_clipping_range():
    g = jnp.eye(4)
    x = jnp.full((4, 3), 100.0)
    for bits in (1, 2, 3, 4):
        z = lattice.babai_round(g, x, bits)
        lo, hi = lattice.int_range(bits)
        assert int(z.max()) <= hi and int(z.min()) >= lo


def test_lll_tightens_babai_bound():
    rng = np.random.default_rng(0)
    # deliberately skewed basis
    g = np.eye(4) + np.triu(rng.normal(size=(4, 4)) * 2.0, 1)
    before = lattice.babai_error_bound(g)
    after = lattice.babai_error_bound(lattice.lll_reduce(g))
    assert after <= before * 1.0 + 1e-9


def test_init_generation_matrix_coverage():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_t(3, size=(8, 4096)), jnp.float32)
    g0 = lattice.init_generation_matrix(v, bits=4)
    coords = jnp.linalg.inv(g0) @ v
    frac_in = float(jnp.mean(jnp.abs(coords) <= 8.0))
    assert frac_in > 0.95   # most coords land inside the 4-bit range


def test_spectral_clip():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    gc = lattice.spectral_clip(g, 0.5, 1.5)
    s = jnp.linalg.svd(gc, compute_uv=False)
    assert float(s.max()) <= 1.5 + 1e-4 and float(s.min()) >= 0.5 - 1e-4
