"""Shared pytest config.

The autouse fixture below clears JAX's trace/executable caches after each
test MODULE.  The suite compiles hundreds of distinct programs across the
families x cache_kinds x backends matrix; on some CPU containers the XLA
compiler segfaults deep into a single long-lived process (reproducible at
the seed commit, mid-`backend_compile`, independent of which tests ran) —
dropping the accumulated executables between modules keeps the per-process
compile history short without changing any test's semantics.  Within a
module, caches persist, so compile-count spy tests are unaffected.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
