"""Fused paged-attention kernel suite: the Pallas block-walk (gather +
dequant + flash SDPA in one pass) against the XLA gather-then-SDPA oracle,
across cache kinds, program widths (decode T=1 / chunk T>1), sliding-window
ring wrap, GQA grouping, uneven slot lengths, tile padding, the no-gather
materialization guarantee, and model/engine-level token parity.

Everything runs Pallas interpret mode off-TPU, so tier-1 covers the kernel
logic on CPU; a real-TPU compiled Mosaic run is a ROADMAP follow-on."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.kernels import attention as attn
from repro.kernels import kv_cache as kvk
from repro.models import registry

PAGED_KINDS = ("paged", "paged_q8", "paged_q8c", "paged_glvq")
TOL = dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# helpers: build populated pools at the kernel level
# ---------------------------------------------------------------------------

def _disjoint_table(rng, slots, bps):
    perm = rng.permutation(np.arange(1, 1 + slots * bps))
    return jnp.asarray(perm.reshape(slots, bps), jnp.int32)


def _filled_cache(rng, mode, table, lens, *, bs, kv, hd, ring=0):
    """Append ``lens[b]`` tokens per slot (block 0 = scratch for finished
    slots).  ``ring > 0`` writes token a to ring slot ``a % ring`` instead
    of linearly — the pre-append sliding-window layout."""
    b, bps = table.shape
    cache = kvk.pool_init(1 + b * bps, bs, kv, hd, jnp.float32, mode)
    for a in range(max(lens)):
        k = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, kv, hd)), jnp.float32)
        slot = a % ring if ring else a
        live = jnp.asarray([a < n for n in lens])
        bids = jnp.where(live, table[:, slot // bs], 0).astype(jnp.int32)
        offs = jnp.full((b,), slot % bs, jnp.int32)
        cache = kvk.append(cache, k, v, bids, offs, mode=mode, backend="xla")
    return cache


def _both(q, cache, table, pos, lens, **kw):
    outs = {be: attn.paged_attention(q, cache, table, pos, lens,
                                     backend=be, **kw)
            for be in ("xla", "pallas")}
    return outs["xla"], outs["pallas"]


# ---------------------------------------------------------------------------
# registry / selection
# ---------------------------------------------------------------------------

def test_attn_backend_registry_and_env(monkeypatch):
    assert set(attn.attn_backends()) >= {"xla", "pallas"}
    monkeypatch.delenv("REPRO_ATTN_BACKEND", raising=False)
    assert attn.resolve_attn_backend("pallas") == "pallas"
    monkeypatch.setenv("REPRO_ATTN_BACKEND", "pallas")
    assert attn.resolve_attn_backend() == "pallas"
    assert attn.resolve_attn_backend("xla") == "xla"  # arg beats env
    monkeypatch.delenv("REPRO_ATTN_BACKEND")
    assert attn.resolve_attn_backend() in attn.attn_backends()
    with pytest.raises(ValueError, match="available"):
        attn.resolve_attn_backend("mosaic9000")


def test_engine_config_validates_attn_backend():
    from repro.serving.engine import EngineConfig
    EngineConfig(attn_backend="pallas")
    with pytest.raises(ValueError):
        EngineConfig(attn_backend="nope")
    with pytest.raises(ValueError):
        EngineConfig(topk_logprobs=-1)


# ---------------------------------------------------------------------------
# kernel-level parity: fused vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", PAGED_KINDS)
@pytest.mark.parametrize("t", (1, 5))
def test_fused_matches_oracle_causal(mode, t):
    """Global (non-window) layers: post-append history, causal prefix mask,
    GQA (4 query heads over 2 KV heads), uneven slot lengths."""
    rng = np.random.default_rng(7)
    b, bps, bs, kv, hd = 3, 3, 4, 2, 16
    h = 2 * kv
    pos = jnp.asarray([6, 2, 9], jnp.int32)           # first query position
    lens = [int(p) + t for p in pos]                  # appended history depth
    table = _disjoint_table(rng, b, bps)
    cache = _filled_cache(rng, mode, table, lens, bs=bs, kv=kv, hd=hd)
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    ref, fused = _both(q, cache, table, pos, jnp.asarray(lens), mode=mode,
                       window=0, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fused), **TOL)


@pytest.mark.parametrize("mode", PAGED_KINDS)
@pytest.mark.parametrize("t,maxpos", ((1, 13), (5, 11), (4, 2)))
def test_fused_matches_oracle_window_ring(mode, t, maxpos):
    """Sliding-window layers: pre-append ring + in-flight chunk keys.

    (t=1, pos 13): decode far past the wrap point; (t=5, pos 11): chunk
    whose ring reads straddle the wrap; (t=4, pos 2): chunk starting before
    the ring has ever filled (some slots have < window history)."""
    rng = np.random.default_rng(11)
    b, bps, bs, kv, hd, window = 3, 2, 4, 2, 16, 8
    h = 2 * kv
    pos = jnp.asarray([maxpos, max(maxpos - 3, 0), max(maxpos - 1, 0)],
                      jnp.int32)
    table = _disjoint_table(rng, b, bps)
    cache = _filled_cache(rng, mode, table, [int(p) for p in pos],
                          bs=bs, kv=kv, hd=hd, ring=window)
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    lens = pos + t
    ref, fused = _both(q, cache, table, pos, lens, mode=mode, window=window,
                       k_chunk=kc, v_chunk=vc, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fused), **TOL)


def test_fused_parity_under_tile_padding(monkeypatch):
    """Non-(8,128)-aligned block shapes: forced tile padding must not change
    the fused result (padded rows are masked dead, outputs sliced back)."""
    rng = np.random.default_rng(3)
    b, bps, bs, kv, hd = 2, 2, 6, 2, 24
    pos = jnp.asarray([5, 9], jnp.int32)
    lens = [int(p) + 1 for p in pos]
    table = _disjoint_table(rng, b, bps)
    cache = _filled_cache(rng, "paged_q8", table, lens, bs=bs, kv=kv, hd=hd)
    q = jnp.asarray(rng.normal(size=(b, 1, 2 * kv, hd)), jnp.float32)
    args = (q, cache, table, pos, jnp.asarray(lens))
    kw = dict(mode="paged_q8", window=0, out_dtype=jnp.float32)
    monkeypatch.delenv("REPRO_KV_FORCE_TILE_PAD", raising=False)
    plain = attn.paged_attention(*args, backend="pallas", **kw)
    monkeypatch.setenv("REPRO_KV_FORCE_TILE_PAD", "1")
    padded = attn.paged_attention(*args, backend="pallas", **kw)
    ref = attn.paged_attention(*args, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(padded), **TOL)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(padded), **TOL)


def test_fused_path_never_materializes_gather(monkeypatch):
    """The whole point of the fusion: the pallas path must not call
    ``kv_cache.gather`` (no dense [B, S, KV, hd] slab in HBM); the xla
    oracle must (that is the unfused baseline it models)."""
    rng = np.random.default_rng(5)
    b, bps, bs, kv, hd = 2, 2, 4, 2, 16
    pos = jnp.asarray([4, 6], jnp.int32)
    lens = [int(p) + 1 for p in pos]
    table = _disjoint_table(rng, b, bps)
    cache = _filled_cache(rng, "paged_q8", table, lens, bs=bs, kv=kv, hd=hd)
    q = jnp.asarray(rng.normal(size=(b, 1, 2 * kv, hd)), jnp.float32)

    calls = []
    real = kvk.gather

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(kvk, "gather", spy)
    attn.paged_attention(q, cache, table, pos, jnp.asarray(lens),
                         mode="paged_q8", backend="pallas")
    assert not calls, "fused path materialized the gather slab"
    attn.paged_attention(q, cache, table, pos, jnp.asarray(lens),
                         mode="paged_q8", backend="xla")
    assert calls, "oracle path should gather"


# ---------------------------------------------------------------------------
# model / engine level: whole-stack token parity, both attention families
# ---------------------------------------------------------------------------

def _greedy_stream(arch, backend, kind="paged_q8"):
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.sampling import SamplingParams
    cfg = reduced(get_config(arch))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(dtype=jnp.float32, cache_kind=kind, block_size=4,
                        attn_backend=backend, chunk_size=3, s_cache=64,
                        slots=3, topk_logprobs=3)
    eng = ServingEngine(params, cfg, ecfg)
    rng = np.random.default_rng(0)
    sp = SamplingParams(max_tokens=6)
    for i in range(4):
        eng.submit(list(map(int, rng.integers(1, cfg.vocab, 11))), sp, rid=i)
    evs = list(eng.stream())
    toks = {r: eng.batcher.finished[r].tokens for r in eng.batcher.finished}
    return toks, evs


@pytest.mark.parametrize("arch", ("llama2-7b", "recurrentgemma-9b"))
def test_engine_token_parity_fused_vs_oracle(arch):
    """End-to-end continuous batching (chunked prefill + decode, global +
    sliding-window layers for the recurrent family): the fused backend must
    reproduce the oracle's greedy token streams bit-for-bit, and every
    TokenEvent must carry a model-distribution logprob + top-k."""
    xla_toks, _ = _greedy_stream(arch, "xla")
    pal_toks, evs = _greedy_stream(arch, "pallas")
    assert xla_toks == pal_toks
    for ev in evs:
        assert ev.logprob is not None and ev.logprob <= 1e-6
        assert len(ev.top_logprobs) == 3
        # greedy: the sampled token IS the top-1 alternative
        assert ev.top_logprobs[0][0] == ev.token
        assert abs(ev.top_logprobs[0][1] - ev.logprob) < 1e-5
        assert ev.top_logprobs[0][1] >= ev.top_logprobs[1][1] \
            >= ev.top_logprobs[2][1]


@pytest.mark.parametrize("arch", ("llama2-7b", "recurrentgemma-9b"))
def test_engine_token_parity_fused_vs_oracle_glvq(arch):
    """paged_glvq end-to-end: the fused block-walk's in-kernel lattice
    decode (codes @ G^T + compand expand + amax) must reproduce the XLA
    gather oracle's greedy token streams bit-for-bit."""
    xla_toks, _ = _greedy_stream(arch, "xla", kind="paged_glvq")
    pal_toks, _ = _greedy_stream(arch, "pallas", kind="paged_glvq")
    assert xla_toks == pal_toks


# ---------------------------------------------------------------------------
# tensor parallel: shard_map over the model axis
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count); covered by the subprocess test on 1 device")
def test_tp_shard_map_parity():
    rng = np.random.default_rng(13)
    b, bps, bs, kv, hd = 2, 2, 4, 2, 16
    pos = jnp.asarray([4, 7], jnp.int32)
    lens = [int(p) + 1 for p in pos]
    table = _disjoint_table(rng, b, bps)
    cache = _filled_cache(rng, "paged_q8", table, lens, bs=bs, kv=kv, hd=hd)
    q = jnp.asarray(rng.normal(size=(b, 1, 2 * kv, hd)), jnp.float32)
    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    args = (q, cache, table, pos, jnp.asarray(lens))
    kw = dict(mode="paged_q8", window=0, out_dtype=jnp.float32)
    ref = attn.paged_attention(*args, backend="xla", **kw)
    tp = attn.paged_attention(*args, backend="pallas", mesh=mesh, **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(tp), **TOL)


def test_tp_shard_map_parity_forced_2dev_subprocess():
    if jax.device_count() >= 2:
        pytest.skip("multi-device run covers this in-process")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__), "-k", "test_tp_shard_map_parity"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
