"""Bit-packing of integer lattice codes into uint32 payloads.

Codes are b-bit two's-complement fields packed ``per_word = 32 // b`` to a
word along the LAST axis (the layer's output dim in our layout). For b = 3
per_word = 10, leaving 2 spare bits per word (6.25% padding) — this is the
only bit-width whose field size does not divide 32; the overhead is included
in the rate accounting of the benchmarks.

The unpack is branch-free (broadcasted shifts + masks), which is exactly what
the Pallas kernel replays on TPU VPU lanes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["per_word", "packed_len", "pack_codes", "unpack_codes",
           "unit_codes"]


def per_word(bits: int) -> int:
    if not 1 <= bits <= 8:
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    return 32 // bits


def unit_codes(bits: int, d: int) -> int:
    """Smallest indivisible run of codes for a (bits, d) payload: a block or
    shard boundary must land on whole uint32 words (per_word codes each) AND
    whole lattice vectors (d codes) — lcm(per_word, d).  The single source of
    this invariant: kernel block sizing (kernels.ops), TP shardability
    (ops.tp_shardable), and the storage specs (parallel.sharding) all agree
    through it."""
    pw = per_word(bits)
    return pw * d // math.gcd(pw, d)


def packed_len(n: int, bits: int) -> int:
    pw = per_word(bits)
    return (n + pw - 1) // pw


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack int codes [..., N] -> uint32 [..., ceil(N / per_word)].

    Codes must lie in the signed b-bit range.
    """
    pw = per_word(bits)
    n = codes.shape[-1]
    n_words = packed_len(n, bits)
    pad = n_words * pw - n
    mask = (1 << bits) - 1
    u = (codes.astype(jnp.int32) & mask).astype(jnp.uint32)
    if pad:
        u = jnp.pad(u, [(0, 0)] * (u.ndim - 1) + [(0, pad)])
    u = u.reshape(u.shape[:-1] + (n_words, pw))
    shifts = (jnp.arange(pw, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    return jnp.sum(u << shifts, axis=-1, dtype=jnp.uint32)


def unpack_codes(words: jax.Array, bits: int, n: int) -> jax.Array:
    """Unpack uint32 [..., W] -> signed int32 codes [..., n]."""
    pw = per_word(bits)
    shifts = (jnp.arange(pw, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    fields = (words[..., None] >> shifts) & jnp.uint32((1 << bits) - 1)
    fields = fields.reshape(words.shape[:-1] + (words.shape[-1] * pw,))[..., :n]
    # sign-extend b-bit two's complement
    f = fields.astype(jnp.int32)
    sign_bit = 1 << (bits - 1)
    return f - 2 * (f & sign_bit)


def packed_nbytes(n_codes: int, bits: int) -> int:
    """Physical bytes used by packing ``n_codes`` b-bit codes."""
    return 4 * packed_len(n_codes, bits)
