"""Shared synthetic fixtures for tests and benchmarks.

Keeps the random packed-payload generator in one place so the backend-parity
tests and the engine benchmark exercise the same payload distribution.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

__all__ = ["synthetic_payload"]


def synthetic_payload(rng: np.random.Generator, k: int, n: int, bits: int,
                      d: int, group_size: int = 128) -> Dict[str, jax.Array]:
    """Random uniform-bit packed payload (codes + G + mu + scale) [K, N]."""
    n_g = k // group_size
    lo = -(2 ** (bits - 1)) if bits > 1 else -1
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 0
    codes = rng.integers(lo, hi + 1, size=(k, n))
    return dict(
        packed=packing.pack_codes(jnp.asarray(codes, jnp.int32), bits),
        g=jnp.asarray(rng.normal(size=(n_g, d, d)) * 0.1 + np.eye(d) * 0.3,
                      jnp.float32),
        mu=jnp.asarray(rng.uniform(10, 250, size=(n_g,)), jnp.float32),
        scale=jnp.asarray(rng.uniform(0.3, 3.0, size=(n_g,)), jnp.float32))
