"""Quantized-weight containers and runtime decode paths.

``QuantLinear`` stores a layer as
  * ``packed``  uint32 [K, n_words]   (b-bit codes packed along the out dim)
  * ``g``       f32   [n_groups, d, d]
  * ``mu``      f32   [n_groups]
  * ``scale``   f32   [n_groups]
plus static metadata (bits, d, group_size, K, N). Mixed-bit layers (SDBA)
are stored as up-to-three uniform-bit segments with a group permutation.

Two decode paths:
  * ``decode_xla``  — pure-jnp unpack + blocked G·Z + inverse companding.
    Used on CPU and in the multi-pod dry-run (Pallas CPU lowering is
    interpret-only); XLA fuses the unpack arithmetic but materializes W.
  * kernels.ops.glvq_matmul — Pallas TPU fused decode+GEMM (see repro.kernels)
    which never materializes W in HBM; selected with use_pallas=True.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import companding, packing
from repro.core.glvq import GLVQConfig, GroupQuant

__all__ = ["QuantLinearMeta", "pack_layer", "decode_xla", "quant_matmul_xla",
           "segment_layer", "QuantSegments"]


@dataclasses.dataclass(frozen=True)
class QuantLinearMeta:
    k: int
    n: int
    bits: int
    d: int
    group_size: int

    @property
    def n_groups(self) -> int:
        return self.k // self.group_size

    @property
    def n_words(self) -> int:
        return packing.packed_len(self.n, self.bits)

    def payload_bytes(self) -> int:
        side = self.n_groups * (2 * self.d * self.d + 2 + 2)  # fp16 G + mu + scale
        return 4 * self.k * self.n_words + side


def pack_layer(q: GroupQuant, cfg: GLVQConfig, bits: int) -> Dict[str, jax.Array]:
    """Pack a uniform-bit GroupQuant into the runtime layout."""
    codes = q["codes"]                       # [n_g, gs, N]
    n_g, gs, n = codes.shape
    flat = codes.reshape(n_g * gs, n)
    packed = packing.pack_codes(flat, bits)  # [K, n_words]
    return dict(packed=packed, g=q["g"], mu=q["mu"], scale=q["scale"])


def decode_xla(payload: Dict[str, jax.Array], meta: QuantLinearMeta) -> jax.Array:
    """Dequantize the full layer: uint32 payload -> f32 W [K, N]."""
    codes = packing.unpack_codes(payload["packed"], meta.bits, meta.n)   # [K, N]
    n_g, gs, d = meta.n_groups, meta.group_size, meta.d
    z = codes.reshape(n_g, gs, meta.n // d, d).astype(jnp.float32)
    # w_vec = G @ z  (vectors along the output dim) == z @ G^T
    y = jnp.einsum("gsvd,ged->gsve", z, payload["g"])
    y = y.reshape(n_g, gs, meta.n)
    w = companding.expand(y, payload["mu"][:, None, None])
    w = w * payload["scale"][:, None, None]
    return w.reshape(meta.k, meta.n)


def quant_matmul_xla(x: jax.Array, payload: Dict[str, jax.Array],
                     meta: QuantLinearMeta, dtype=jnp.bfloat16) -> jax.Array:
    """y = x @ dequant(W) via the XLA path."""
    w = decode_xla(payload, meta).astype(dtype)
    return x @ w


# ---------------------------------------------------------------------------
# Mixed-bit (SDBA) segmented storage
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantSegments:
    """Mixed-precision layer = list of (meta, payload, group_indices)."""
    segments: List[Tuple[QuantLinearMeta, Dict[str, jax.Array], np.ndarray]]
    k: int
    n: int
    group_size: int

    def payload_bytes(self) -> int:
        return sum(m.payload_bytes() for m, _, _ in self.segments)

    def avg_bits(self) -> float:
        tot = sum(m.bits * len(idx) for m, _, idx in self.segments)
        cnt = sum(len(idx) for _, _, idx in self.segments)
        return tot / cnt


def segment_layer(q: GroupQuant, cfg: GLVQConfig) -> QuantSegments:
    """Split a mixed-bit GroupQuant into uniform-bit packed segments."""
    bits = np.asarray(q["bits"])
    n_g, gs, n = q["codes"].shape
    segs = []
    for b in sorted(set(bits.tolist())):
        idx = np.nonzero(bits == b)[0]
        sub = GroupQuant(
            codes=q["codes"][idx], g=q["g"][idx], mu=q["mu"][idx],
            scale=q["scale"][idx], bits=q["bits"][idx])
        payload = pack_layer(sub, cfg, int(b))
        meta = QuantLinearMeta(k=len(idx) * gs, n=n, bits=int(b), d=cfg.d,
                               group_size=gs)
        segs.append((meta, payload, idx))
    return QuantSegments(segments=segs, k=n_g * gs, n=n, group_size=gs)


# ---------------------------------------------------------------------------
# Whole-model quantized parameter trees (serving path)
# ---------------------------------------------------------------------------

QUANTIZABLE = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "wx", "wg", "wr",
               "wi", "in_proj", "out_proj", "router"}

_PAYLOAD_KEYS = {"packed", "g", "mu", "scale"}


def _meta_key(names: Tuple[str, ...]) -> Tuple[str, ...]:
    """Stable key for a weight independent of stack/tail container position:
    the (block-kind, weight-name) suffix, e.g. ("attn", "wq")."""
    return tuple(names[-2:])


def quantized_param_shapes(params_sds, *, bits: int, d: int,
                           group_size: int = 128):
    """SDS transform: replace quantizable weights with packed payload SDS.

    Leading stack/expert dims of a weight [lead..., K, N] are PRESERVED on
    the payload (packed [lead..., K, n_words]) so per-layer slices decode
    inside the model's scan — the paper's streaming decode (Sec. 3.4).
    Returns (new_sds_tree, meta_by_key) — no device data is touched.
    """
    meta = {}

    def conv(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        name = names[-1] if names else ""
        if name in QUANTIZABLE and leaf.ndim >= 2:
            lead, (k, n) = leaf.shape[:-2], leaf.shape[-2:]
            if k % group_size == 0 and n % d == 0:
                m = QuantLinearMeta(k=k, n=n, bits=bits, d=d,
                                    group_size=group_size)
                meta[_meta_key(names)] = m
                n_g = k // group_size
                return dict(
                    packed=jax.ShapeDtypeStruct(lead + (k, m.n_words), jnp.uint32),
                    g=jax.ShapeDtypeStruct(lead + (n_g, d, d), jnp.float32),
                    mu=jax.ShapeDtypeStruct(lead + (n_g,), jnp.float32),
                    scale=jax.ShapeDtypeStruct(lead + (n_g,), jnp.float32),
                )
        return leaf

    new = jax.tree_util.tree_map_with_path(conv, params_sds)
    return new, meta


def quantize_param_tree(params, *, cfg: GLVQConfig, bits: Optional[int] = None,
                        h_by_key: Optional[Dict] = None):
    """Offline: run GLVQ on every quantizable weight (uniform bit-width).

    Stacked weights [lead..., K, N] are quantized per unstacked layer (groups
    never cross layer boundaries). Returns (quantized tree, meta_by_key).
    """
    from repro.core import glvq as glvq_lib
    bits = bits if bits is not None else cfg.bits
    meta = {}

    def conv(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        name = names[-1] if names else ""
        if name in QUANTIZABLE and leaf.ndim >= 2:
            lead, (k, n) = leaf.shape[:-2], leaf.shape[-2:]
            if k % cfg.group_size == 0 and n % cfg.d == 0:
                w = leaf.reshape((-1, k, n))
                h = h_by_key.get(_meta_key(names)) if h_by_key else None
                payloads = []
                for i in range(w.shape[0]):
                    q = glvq_lib.quantize_layer(w[i], h, cfg)
                    payloads.append(pack_layer(q, cfg, bits))
                payload = jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
                    lead + xs[0].shape), *payloads)
                m = QuantLinearMeta(k=k, n=n, bits=bits, d=cfg.d,
                                    group_size=cfg.group_size)
                meta[_meta_key(names)] = m
                return payload
        return leaf

    new = jax.tree_util.tree_map_with_path(conv, params)
    return new, meta


def _decode_any(payload: Dict[str, jax.Array], m: QuantLinearMeta, dtype):
    """Decode a payload with arbitrary leading stack dims."""
    packed = payload["packed"]
    lead = packed.shape[:-2]
    if not lead:
        return decode_xla(payload, m).astype(dtype)
    flat = {k: v.reshape((-1,) + v.shape[len(lead):]) for k, v in payload.items()}
    w = jax.vmap(lambda p: decode_xla(p, m))(flat)
    return w.reshape(lead + (m.k, m.n)).astype(dtype)


def materialize_tree(qparams, meta_by_key, dtype=jnp.bfloat16):
    """Inside-jit decode: payload dicts -> dense weights (original shapes).

    Works on the full tree or on any subtree (e.g. a per-layer slice inside
    jax.lax.scan — the streaming-decode path)."""

    def rebuild(node, names=()):
        if isinstance(node, dict) and set(node) == _PAYLOAD_KEYS \
                and _meta_key(names) in meta_by_key:
            return _decode_any(node, meta_by_key[_meta_key(names)], dtype)
        if isinstance(node, dict):
            return {k: rebuild(v, names + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rebuild(v, names) for v in node)
        return node

    return rebuild(qparams)


def decode_segments(qs: QuantSegments) -> jax.Array:
    """Reassemble the full [K, N] weight from mixed-bit segments."""
    w = jnp.zeros((qs.k // qs.group_size, qs.group_size, qs.n), jnp.float32)
    for meta, payload, idx in qs.segments:
        wseg = decode_xla(payload, meta).reshape(len(idx), qs.group_size, qs.n)
        w = w.at[jnp.asarray(idx)].set(wseg)
    return w.reshape(qs.k, qs.n)
