"""Quantized-weight containers and runtime decode paths.

``QuantLinear`` stores a layer as
  * ``packed``  uint32 [K, n_words]   (b-bit codes packed along the out dim)
  * ``g``       f32   [n_groups, d, d]
  * ``mu``      f32   [n_groups]
  * ``scale``   f32   [n_groups]
plus static metadata (bits, d, group_size, K, N). Mixed-bit layers (SDBA)
are stored as up-to-three uniform-bit segments with a group permutation.

Runtime execution lives in the quantized-execution engine: payload dicts are
wrapped into ``repro.core.qtensor.QuantTensor`` nodes whose ``matmul`` /
``dense`` dispatch through the backend registry in ``repro.kernels.ops``
(``pallas_fused`` fused decode+GEMM on TPU, ``xla_decode`` elsewhere,
``reference`` oracle).  ``decode_xla`` below is the canonical unpack +
blocked G·Z + inverse-companding decode the ``xla_decode`` backend calls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import companding, packing
from repro.core.glvq import GLVQConfig, GroupQuant

__all__ = ["QuantLinearMeta", "pack_layer", "decode_xla",
           "segment_layer", "QuantSegments", "materialize_tree",
           "decode_segments", "quantize_param_tree", "quantized_param_shapes"]


@dataclasses.dataclass(frozen=True)
class QuantLinearMeta:
    k: int
    n: int
    bits: int
    d: int
    group_size: int

    @property
    def n_groups(self) -> int:
        return self.k // self.group_size

    @property
    def n_words(self) -> int:
        return packing.packed_len(self.n, self.bits)

    def payload_bytes(self) -> int:
        side = self.n_groups * (2 * self.d * self.d + 2 + 2)  # fp16 G + mu + scale
        return 4 * self.k * self.n_words + side


def pack_layer(q: GroupQuant, cfg: GLVQConfig, bits: int) -> Dict[str, jax.Array]:
    """Pack a uniform-bit GroupQuant into the runtime layout."""
    codes = q["codes"]                       # [n_g, gs, N]
    n_g, gs, n = codes.shape
    flat = codes.reshape(n_g * gs, n)
    packed = packing.pack_codes(flat, bits)  # [K, n_words]
    return dict(packed=packed, g=q["g"], mu=q["mu"], scale=q["scale"])


def decode_xla(payload: Dict[str, jax.Array], meta: QuantLinearMeta) -> jax.Array:
    """Dequantize the full layer: uint32 payload -> f32 W [K, N]."""
    codes = packing.unpack_codes(payload["packed"], meta.bits, meta.n)   # [K, N]
    n_g, gs, d = meta.n_groups, meta.group_size, meta.d
    z = codes.reshape(n_g, gs, meta.n // d, d).astype(jnp.float32)
    # w_vec = G @ z  (vectors along the output dim) == z @ G^T
    y = jnp.einsum("gsvd,ged->gsve", z, payload["g"])
    y = y.reshape(n_g, gs, meta.n)
    w = companding.expand(y, payload["mu"][:, None, None])
    w = w * payload["scale"][:, None, None]
    return w.reshape(meta.k, meta.n)


# ---------------------------------------------------------------------------
# Mixed-bit (SDBA) segmented storage
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantSegments:
    """Mixed-precision layer = list of (meta, payload, group_indices)."""
    segments: List[Tuple[QuantLinearMeta, Dict[str, jax.Array], np.ndarray]]
    k: int
    n: int
    group_size: int

    def payload_bytes(self) -> int:
        return sum(m.payload_bytes() for m, _, _ in self.segments)

    def avg_bits(self) -> float:
        tot = sum(m.bits * len(idx) for m, _, idx in self.segments)
        cnt = sum(len(idx) for _, _, idx in self.segments)
        return tot / cnt


def segment_layer(q: GroupQuant, cfg: GLVQConfig) -> QuantSegments:
    """Split a mixed-bit GroupQuant into uniform-bit packed segments."""
    bits = np.asarray(q["bits"])
    n_g, gs, n = q["codes"].shape
    segs = []
    for b in sorted(set(bits.tolist())):
        idx = np.nonzero(bits == b)[0]
        sub = GroupQuant(
            codes=q["codes"][idx], g=q["g"][idx], mu=q["mu"][idx],
            scale=q["scale"][idx], bits=q["bits"][idx])
        payload = pack_layer(sub, cfg, int(b))
        meta = QuantLinearMeta(k=len(idx) * gs, n=n, bits=int(b), d=cfg.d,
                               group_size=gs)
        segs.append((meta, payload, idx))
    return QuantSegments(segments=segs, k=n_g * gs, n=n, group_size=gs)


# ---------------------------------------------------------------------------
# Whole-model quantized parameter trees (serving path)
# ---------------------------------------------------------------------------

QUANTIZABLE = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "wx", "wg", "wr",
               "wi", "in_proj", "out_proj", "router"}

# Megatron-style tensor parallelism over quantized layers: the TP_ROW
# weights shard K (whole code groups) and psum partial products; every other
# quantizable weight is column-parallel and shards the packed codes along N
# (n_words).  The sharding rules (parallel.sharding) and the QuantTensor wrap
# (core.qtensor) both key off this set so storage layout and compute layout
# cannot drift.
TP_ROW = {"wo", "w2", "out_proj"}

_PAYLOAD_KEYS = {"packed", "g", "mu", "scale"}


def _meta_key(names: Tuple[str, ...]) -> Tuple[str, ...]:
    """Stable key for a weight independent of stack/tail container position:
    the (block-kind, weight-name) suffix, e.g. ("attn", "wq")."""
    return tuple(names[-2:])


def quantized_param_shapes(params_sds, *, bits: int, d: int,
                           group_size: int = 128):
    """SDS transform: replace quantizable weights with packed payload SDS.

    Leading stack/expert dims of a weight [lead..., K, N] are PRESERVED on
    the payload (packed [lead..., K, n_words]) so per-layer slices decode
    inside the model's scan — the paper's streaming decode (Sec. 3.4).
    Returns (new_sds_tree, meta_by_key) — no device data is touched.
    """
    meta = {}

    def conv(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        name = names[-1] if names else ""
        if name in QUANTIZABLE and leaf.ndim >= 2:
            lead, (k, n) = leaf.shape[:-2], leaf.shape[-2:]
            if k % group_size == 0 and n % d == 0:
                m = QuantLinearMeta(k=k, n=n, bits=bits, d=d,
                                    group_size=group_size)
                meta[_meta_key(names)] = m
                n_g = k // group_size
                return dict(
                    packed=jax.ShapeDtypeStruct(lead + (k, m.n_words), jnp.uint32),
                    g=jax.ShapeDtypeStruct(lead + (n_g, d, d), jnp.float32),
                    mu=jax.ShapeDtypeStruct(lead + (n_g,), jnp.float32),
                    scale=jax.ShapeDtypeStruct(lead + (n_g,), jnp.float32),
                )
        return leaf

    new = jax.tree_util.tree_map_with_path(conv, params_sds)
    return new, meta


def quantize_param_tree(params, *, cfg: GLVQConfig, bits: Optional[int] = None,
                        h_by_key: Optional[Dict] = None):
    """Offline: run GLVQ on every quantizable weight (uniform bit-width).

    Stacked weights [lead..., K, N] are quantized per unstacked layer (groups
    never cross layer boundaries). Returns (quantized tree, meta_by_key).
    """
    from repro.core import glvq as glvq_lib
    bits = bits if bits is not None else cfg.bits
    meta = {}

    def conv(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        name = names[-1] if names else ""
        if name in QUANTIZABLE and leaf.ndim >= 2:
            lead, (k, n) = leaf.shape[:-2], leaf.shape[-2:]
            if k % cfg.group_size == 0 and n % cfg.d == 0:
                w = leaf.reshape((-1, k, n))
                h = h_by_key.get(_meta_key(names)) if h_by_key else None
                payloads = []
                for i in range(w.shape[0]):
                    q = glvq_lib.quantize_layer(w[i], h, cfg)
                    payloads.append(pack_layer(q, cfg, bits))
                payload = jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
                    lead + xs[0].shape), *payloads)
                m = QuantLinearMeta(k=k, n=n, bits=bits, d=cfg.d,
                                    group_size=cfg.group_size)
                meta[_meta_key(names)] = m
                return payload
        return leaf

    new = jax.tree_util.tree_map_with_path(conv, params)
    return new, meta


def materialize_tree(qparams, meta_by_key, dtype=jnp.bfloat16):
    """Materialize every payload in the tree to a dense weight.

    Back-compat alias for :func:`repro.core.qtensor.dense_tree` — explicit
    materialization is the opt-in path (CPU dry-runs, fake-quant eval); the
    model hot path wraps payloads into QuantTensor and dispatches matmuls."""
    from repro.core import qtensor
    return qtensor.dense_tree(qparams, meta_by_key, dtype)


def decode_segments(qs: QuantSegments) -> jax.Array:
    """Reassemble the full [K, N] weight from mixed-bit segments."""
    from repro.core import qtensor
    return qtensor.QuantTensor.from_segments(qs).dense(jnp.float32)
