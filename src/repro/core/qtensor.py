"""QuantTensor: a first-class quantized weight with backend-dispatched matmul.

A ``QuantTensor`` bundles one or more uniform-bit packed payloads with their
``QuantLinearMeta`` (mixed-bit SDBA layers carry one segment per bit-width
plus the group permutation) and exposes the two operations the rest of the
system needs:

  * ``qt.matmul(x)`` / ``x @ qt`` — y = x @ dequant(W), dispatched through
    the backend registry in ``repro.kernels.ops`` (``pallas_fused`` on TPU
    never materializes W in HBM; ``xla_decode`` on CPU; ``reference`` oracle).
  * ``qt.dense(dtype)`` — explicit materialization, the opt-in for CPU
    dry-runs and fake-quant evaluation.

``QuantTensor`` is a registered jax pytree: payload arrays are children (so
``jax.lax.scan`` slices a stacked [R, ...] weight into per-layer tensors,
``jax.jit`` traces through it, and shardings apply), while metas / group
indices / dispatch hints are static aux data.

Layout convention (matches ``core.quantized``):
  packed  uint32 [lead..., K, n_words]   b-bit codes packed along N
  g       f32    [lead..., n_groups, d, d]
  mu      f32    [lead..., n_groups]
  scale   f32    [lead..., n_groups]
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantized import (QuantLinearMeta, QuantSegments, TP_ROW,
                                  _PAYLOAD_KEYS, _meta_key)

__all__ = ["QuantTensor", "matmul_cols", "wrap_tree", "dense_tree"]


@jax.tree_util.register_pytree_node_class
class QuantTensor:
    """Quantized [lead..., K, N] weight = segments of packed payloads + meta."""

    def __init__(self, payloads: Tuple[Dict[str, Any], ...],
                 metas: Tuple[QuantLinearMeta, ...],
                 group_index: Optional[Tuple[Tuple[int, ...], ...]],
                 k: int, n: int, group_size: int,
                 out_dtype=None, backend: Optional[str] = None,
                 mesh=None, tp: Optional[str] = None):
        self.payloads = tuple(payloads)
        self.metas = tuple(metas)
        self.group_index = group_index
        self.k = k
        self.n = n
        self.group_size = group_size
        self.out_dtype = out_dtype
        self.backend = backend
        self.mesh = mesh            # jax Mesh -> shard_map TP execution
        self.tp = tp                # "column" | "row" | None

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_payload(cls, payload: Dict[str, Any], meta: QuantLinearMeta, *,
                     backend: Optional[str] = None, out_dtype=None,
                     mesh=None, tp: Optional[str] = None) -> "QuantTensor":
        """Uniform-bit layer (possibly with leading stack dims)."""
        return cls(payloads=(dict(payload),), metas=(meta,), group_index=None,
                   k=meta.k, n=meta.n, group_size=meta.group_size,
                   out_dtype=out_dtype, backend=backend, mesh=mesh, tp=tp)

    @classmethod
    def from_segments(cls, segs: QuantSegments, *,
                      backend: Optional[str] = None, out_dtype=None,
                      mesh=None, tp: Optional[str] = None) -> "QuantTensor":
        """Mixed-bit (SDBA) layer: one segment per bit-width."""
        metas = tuple(m for m, _, _ in segs.segments)
        payloads = tuple(dict(p) for _, p, _ in segs.segments)
        gidx = tuple(tuple(int(i) for i in np.asarray(idx))
                     for _, _, idx in segs.segments)
        return cls(payloads=payloads, metas=metas, group_index=gidx,
                   k=segs.k, n=segs.n, group_size=segs.group_size,
                   out_dtype=out_dtype, backend=backend, mesh=mesh, tp=tp)

    # -- pytree --------------------------------------------------------------

    def tree_flatten(self):
        aux = (self.metas, self.group_index, self.k, self.n, self.group_size,
               self.out_dtype, self.backend, self.mesh, self.tp)
        return (self.payloads,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        metas, gidx, k, n, gs, out_dtype, backend, mesh, tp = aux
        return cls(payloads=children[0], metas=metas, group_index=gidx,
                   k=k, n=n, group_size=gs, out_dtype=out_dtype,
                   backend=backend, mesh=mesh, tp=tp)

    # -- properties ----------------------------------------------------------

    @property
    def is_mixed(self) -> bool:
        return len(self.payloads) > 1 or self.group_index is not None

    @property
    def lead_shape(self) -> Tuple[int, ...]:
        return tuple(self.payloads[0]["packed"].shape[:-2])

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.lead_shape + (self.k, self.n)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    def avg_bits(self) -> float:
        tot = sum(m.bits * (m.k // m.group_size) for m in self.metas)
        cnt = sum(m.k // m.group_size for m in self.metas)
        return tot / cnt

    def payload_bytes(self) -> int:
        n_stack = int(np.prod(self.lead_shape)) if self.lead_shape else 1
        return n_stack * sum(m.payload_bytes() for m in self.metas)

    def __repr__(self):
        kind = "mixed" if self.is_mixed else f"{self.metas[0].bits}b"
        return (f"QuantTensor({kind}, shape={self.shape}, "
                f"d={self.metas[0].d}, gs={self.group_size})")

    # -- dispatch ------------------------------------------------------------

    def astype(self, dtype) -> "QuantTensor":
        """Record the compute dtype for subsequent matmuls (keeps the
        ``x @ w.astype(x.dtype)`` idiom working unchanged on quantized trees)."""
        return QuantTensor(self.payloads, self.metas, self.group_index,
                           self.k, self.n, self.group_size,
                           out_dtype=jnp.dtype(dtype), backend=self.backend,
                           mesh=self.mesh, tp=self.tp)

    def with_backend(self, backend: Optional[str]) -> "QuantTensor":
        return QuantTensor(self.payloads, self.metas, self.group_index,
                           self.k, self.n, self.group_size,
                           out_dtype=self.out_dtype, backend=backend,
                           mesh=self.mesh, tp=self.tp)

    def with_mesh(self, mesh, tp: Optional[str] = "column") -> "QuantTensor":
        """Bind a device mesh + TP mode: subsequent matmuls run the shard_map
        path on the local payload slice (``kernels.ops.quant_matmul_tp``)."""
        return QuantTensor(self.payloads, self.metas, self.group_index,
                           self.k, self.n, self.group_size,
                           out_dtype=self.out_dtype, backend=self.backend,
                           mesh=mesh, tp=tp if mesh is not None else None)

    def matmul(self, x, *, backend: Optional[str] = None, out_dtype=None,
               zipped: Optional[bool] = None):
        """y[..., N] = x[..., K] @ dequant(self), backend-dispatched.

        Stacked tensors ([lead..., K, N]): ``zipped=True`` pairs x's leading
        dims with the stack dims (slice i of x hits slice i of W — MoE
        experts); ``zipped=False`` broadcasts x against every slice.
        ``zipped=None`` auto-detects (zipped iff x's leading dims equal the
        stack dims) — pass it explicitly when x could legitimately carry
        batch dims that coincide with the stack shape.
        """
        from repro.kernels import ops
        backend = backend if backend is not None else self.backend
        out_dtype = out_dtype or self.out_dtype or x.dtype
        tp_mesh = self.mesh if self.tp is not None else None
        lead = self.lead_shape
        if not lead:
            if tp_mesh is not None:
                if not self.is_mixed:
                    return ops.quant_matmul_tp(
                        x, self.payloads[0], self.metas[0], mesh=tp_mesh,
                        parallel=self.tp, backend=backend,
                        out_dtype=out_dtype)
                return ops.quant_matmul_segments_tp(
                    x, list(zip(self.metas, self.payloads, self.group_index)),
                    self.group_size, self.n, mesh=tp_mesh, parallel=self.tp,
                    backend=backend, out_dtype=out_dtype)
            if not self.is_mixed:
                return ops.quant_matmul(x, self.payloads[0], self.metas[0],
                                        backend=backend, out_dtype=out_dtype)
            return ops.quant_matmul_segments(
                x, list(zip(self.metas, self.payloads, self.group_index)),
                self.group_size, self.n, backend=backend, out_dtype=out_dtype)
        if self.is_mixed:
            raise NotImplementedError(
                "stacked mixed-bit QuantTensor matmul is not supported; "
                "segment layers are stored unstacked")
        nlead = len(lead)
        auto_zip = x.ndim >= nlead + 2 and x.shape[:nlead] == lead
        if zipped is None:
            zipped = auto_zip
        if tp_mesh is None and zipped == auto_zip \
                and ops.resolve_backend(backend) == "xla_decode":
            # one batched decode + one (broadcasting) matmul: keeps the HLO
            # size constant in the number of stacked slices (MoE experts);
            # jnp.matmul's broadcasting matches the requested zip semantics
            # exactly when zipped == auto_zip
            w = ops.quant_decode(self.payloads[0], self.metas[0],
                                 dtype=x.dtype)
            return jnp.matmul(x, w).astype(out_dtype)
        size = int(np.prod(lead))
        payload = {key: v.reshape((size,) + v.shape[nlead:])
                   for key, v in self.payloads[0].items()}
        if zipped:
            xf = x.reshape((size,) + x.shape[nlead:])
        outs = []
        for i in range(size):
            pl_i = {key: v[i] for key, v in payload.items()}
            xi = xf[i] if zipped else x
            if tp_mesh is not None:
                outs.append(ops.quant_matmul_tp(xi, pl_i, self.metas[0],
                                                mesh=tp_mesh,
                                                parallel=self.tp,
                                                backend=backend,
                                                out_dtype=out_dtype))
            else:
                outs.append(ops.quant_matmul(xi, pl_i, self.metas[0],
                                             backend=backend,
                                             out_dtype=out_dtype))
        return jnp.stack(outs).reshape(lead + outs[0].shape)

    def __rmatmul__(self, x):
        return self.matmul(x)

    def dense(self, dtype=jnp.float32):
        """Materialize the dense weight [lead..., K, N] — explicit opt-in."""
        from repro.kernels import ops
        if not self.is_mixed:
            return ops.quant_decode(self.payloads[0], self.metas[0],
                                    dtype=dtype)
        gs = self.group_size
        w = jnp.zeros((self.k // gs, gs, self.n), jnp.float32)
        for meta, payload, idx in zip(self.metas, self.payloads,
                                      self.group_index):
            seg = ops.quant_decode(payload, meta, dtype=jnp.float32)
            w = w.at[jnp.asarray(idx)].set(seg.reshape(len(idx), gs, self.n))
        return w.reshape(self.k, self.n).astype(dtype)


def matmul_cols(ws: Sequence["QuantTensor"], x, *, out_dtype=None):
    """Fused column-group matmul: (x @ w for w in ws) in ONE engine dispatch.

    The q/k/v (or gate/up) projections of a block all contract the same
    activations; fusing them streams the activation slab once and — on
    ``xla_decode`` — runs a single [M, K] x [K, sum(N_i)] GEMM instead of one
    GEMM per weight.  Falls back to per-weight dispatch when the group can't
    fuse (mixed-bit segments, stacked payloads, TP meshes, or disagreeing
    backends / K).  Returns a tuple of per-weight outputs."""
    from repro.kernels import ops
    ws = tuple(ws)
    fusable = (len(ws) > 1
               and all(isinstance(w, QuantTensor) for w in ws)
               and not any(w.is_mixed or w.lead_shape for w in ws)
               and all(w.mesh is None or w.tp is None for w in ws)
               and len({w.backend for w in ws}) == 1
               and len({w.k for w in ws}) == 1)
    if not fusable:
        return tuple(w.matmul(x, out_dtype=out_dtype) for w in ws)
    out_dtype = out_dtype or ws[0].out_dtype or x.dtype
    y = ops.quant_matmul_cols(x, [(w.payloads[0], w.metas[0]) for w in ws],
                              backend=ws[0].backend, out_dtype=out_dtype)
    splits = np.cumsum([w.n for w in ws])[:-1].tolist()
    return tuple(jnp.split(y, splits, axis=-1))


# ---------------------------------------------------------------------------
# Whole-tree wrapping (the model / serving entry point)
# ---------------------------------------------------------------------------

def wrap_tree(tree, meta_by_key: Dict, *, backend: Optional[str] = None,
              mesh=None):
    """Replace packed-payload dicts with QuantTensor nodes.

    Walks the param tree exactly like ``core.quantized`` does when packing:
    a dict with keys {packed, g, mu, scale} whose (block-kind, weight-name)
    suffix appears in ``meta_by_key`` becomes one QuantTensor.  Works on the
    full tree or any subtree; on concrete arrays, tracers, or SDS stand-ins.

    With ``mesh``, every QuantTensor binds the mesh plus its Megatron TP mode
    by weight name (``TP_ROW`` weights run row-parallel K-sharded psum,
    everything else column-parallel N-sharded) so ``x @ qt`` executes the
    shard_map path on the local payload slice.
    """
    def rebuild(node, names=()):
        if isinstance(node, dict) and set(node) == set(_PAYLOAD_KEYS) \
                and _meta_key(names) in meta_by_key:
            tp = None
            if mesh is not None:
                tp = "row" if (names and names[-1] in TP_ROW) else "column"
            return QuantTensor.from_payload(node, meta_by_key[_meta_key(names)],
                                            backend=backend, mesh=mesh, tp=tp)
        if isinstance(node, dict):
            return {k: rebuild(v, names + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rebuild(v, names) for v in node)
        return node

    return rebuild(tree)


def dense_tree(tree, meta_by_key: Dict, dtype=jnp.bfloat16):
    """Materialize every quantized weight in the tree (explicit opt-in for
    CPU dry-runs / fake-quant eval; the serving path uses wrap_tree)."""
    wrapped = wrap_tree(tree, meta_by_key)
    return jax.tree_util.tree_map(
        lambda n: n.dense(dtype) if isinstance(n, QuantTensor) else n,
        wrapped, is_leaf=lambda n: isinstance(n, QuantTensor))
