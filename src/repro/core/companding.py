"""Group-specific mu-law companding (paper Eq. 9, 12).

F_mu(x)    = sgn(x) * ln(1 + mu|x|) / ln(1 + mu)         (|x| <= 1)
F_mu^-1(y) = sgn(y) * ((1 + mu)^{|y|} - 1) / mu

mu is learned per group jointly with the generation matrix; the init is
mu0 = 100 * tanh(kurtosis / 10), projected to [MU_MIN, MU_MAX] after each
update. Weights are normalized by their group max-abs before companding
(the scale is fp16 side information) so that |x| <= 1 holds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MU_MIN = 10.0
MU_MAX = 255.0

__all__ = ["MU_MIN", "MU_MAX", "compand", "expand", "init_mu", "project_mu", "kurtosis"]


def compand(x: jax.Array, mu: jax.Array) -> jax.Array:
    """F_mu(x); x expected in [-1, 1]."""
    mu = jnp.asarray(mu, x.dtype)
    return jnp.sign(x) * jnp.log1p(mu * jnp.abs(x)) / jnp.log1p(mu)


def expand(y: jax.Array, mu: jax.Array) -> jax.Array:
    """F_mu^{-1}(y)."""
    mu = jnp.asarray(mu, y.dtype)
    return jnp.sign(y) * jnp.expm1(jnp.abs(y) * jnp.log1p(mu)) / mu


def kurtosis(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Sample (excess-free, i.e. plain) kurtosis of a flat array."""
    x = x.reshape(-1).astype(jnp.float32)
    m = jnp.mean(x)
    c = x - m
    var = jnp.mean(c * c)
    m4 = jnp.mean(c ** 4)
    return m4 / (var * var + eps)


def init_mu(group_weights: jax.Array) -> jax.Array:
    """Paper Eq. 12: mu0 = 100 tanh(kappa / 10), projected into range."""
    kappa = kurtosis(group_weights)
    return project_mu(100.0 * jnp.tanh(kappa / 10.0))


def project_mu(mu: jax.Array) -> jax.Array:
    return jnp.clip(mu, MU_MIN, MU_MAX)
