"""PTQ baselines the paper compares against / ablates.

* RTN       — round-to-nearest, symmetric, per (input-group x output column).
* GPTQ      — data-aware column-wise quantization with Hessian error
              propagation (Frantar et al. 2022), blocked Cholesky form.
* Fixed-lattice — GLVQ pipeline with a frozen shared basis (QuIP#-style E8
              for d=8, scaled identity otherwise): the paper's Table 7 ablation.
* GCD       — GLVQ with greedy-coordinate-descent index assignment (Table 12).

All operate on W [K, N] with y = x @ W, matching repro.core.glvq layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.linalg

from repro.core import glvq as glvq_lib
from repro.core import lattice

__all__ = ["rtn_quantize", "gptq_quantize", "fixed_lattice_config", "e8_basis"]


def rtn_quantize(w: jax.Array, bits: int, group_size: int = 128) -> jax.Array:
    """Symmetric RTN with per-(group, column) scales. Returns dequantized W."""
    k, n = w.shape
    n_g = k // group_size
    wg = w.astype(jnp.float32).reshape(n_g, group_size, n)
    qmax = 2.0 ** (bits - 1) - 1 if bits > 1 else 1.0
    scale = jnp.max(jnp.abs(wg), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(wg / scale), -qmax - (0 if bits == 1 else 1), qmax)
    return (q * scale).reshape(k, n).astype(w.dtype)


def gptq_quantize(
    w: jax.Array,
    h: jax.Array,
    bits: int,
    group_size: int = 128,
    percdamp: float = 0.01,
    block: int = 128,
) -> jax.Array:
    """GPTQ over the input dim (rows of W [K, N]); H = X X^T is [K, K].

    Column-major GPTQ quantizes one input channel at a time and spreads the
    error over the not-yet-quantized channels using the Cholesky of H^{-1}.
    Runs in numpy float64 (offline, calibration-time).
    """
    w_np = np.asarray(w, np.float64).copy()          # [K, N]
    h_np = np.asarray(h, np.float64).copy()
    k, n = w_np.shape

    dead = np.diag(h_np) == 0
    h_np[dead, dead] = 1.0
    w_np[dead, :] = 0.0
    damp = percdamp * np.mean(np.diag(h_np))
    h_np[np.diag_indices(k)] += damp

    hinv = np.linalg.inv(h_np)
    # upper Cholesky factor U with H^{-1} = U^T U
    hinv_u = scipy.linalg.cholesky(hinv, lower=False)

    qmax = 2.0 ** (bits - 1) - 1 if bits > 1 else 1.0
    out = np.zeros_like(w_np)
    scale = np.zeros((1, n))
    for i1 in range(0, k, block):
        i2 = min(i1 + block, k)
        w_blk = w_np[i1:i2, :].copy()
        err_blk = np.zeros_like(w_blk)
        u_blk = hinv_u[i1:i2, i1:i2]
        for i in range(i2 - i1):
            gi = i1 + i
            if gi % group_size == 0:
                g_rows = w_np[gi : gi + group_size, :]
                scale = np.maximum(np.max(np.abs(g_rows), axis=0, keepdims=True) / qmax, 1e-12)
            d = u_blk[i, i]
            q = np.clip(np.round(w_blk[i, :] / scale[0]), -qmax - (0 if bits == 1 else 1), qmax)
            dq = q * scale[0]
            out[gi, :] = dq
            err = (w_blk[i, :] - dq) / d
            if i + 1 < i2 - i1:
                w_blk[i + 1 :, :] -= np.outer(u_blk[i, i + 1 :], err)
            err_blk[i, :] = err
        if i2 < k:
            w_np[i2:, :] -= hinv_u[i1:i2, i2:].T @ err_blk
    return jnp.asarray(out, dtype=w.dtype)


def e8_basis() -> np.ndarray:
    """Generator of the E8 lattice (Conway & Sloane), det = 1.

    Rows of the standard generator; we return columns-as-basis-vectors.
    """
    g = np.zeros((8, 8))
    g[0, 0] = 2.0
    for i in range(1, 7):
        g[i, i - 1] = -1.0
        g[i, i] = 1.0
    g[7, :] = 0.5
    return g.T


def fixed_lattice_config(cfg: glvq_lib.GLVQConfig) -> glvq_lib.GLVQConfig:
    """Ablation: same pipeline, frozen (shared) lattice basis."""
    return dataclasses.replace(cfg, learn_lattice=False)


def fixed_lattice_init(d: int, bits: int, data_std: float = 1.0) -> jnp.ndarray:
    """Shared basis for the fixed-lattice ablation: scaled E8 for d=8,
    scaled identity (product lattice == vector RTN) otherwise."""
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 1
    scale = 3.0 * data_std / max(hi + 0.5, 1.0)
    if d == 8:
        base = e8_basis()
        base = base / np.abs(np.linalg.det(base)) ** (1.0 / d)
        return jnp.asarray(scale * base, jnp.float32)
    return jnp.asarray(scale * np.eye(d), jnp.float32)
