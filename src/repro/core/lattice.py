"""Lattice primitives: Babai rounding, generation-matrix init, spectral clipping.

A lattice is {G z | z in Z^d} for a full-rank generation matrix G (d x d).
Encoding approximates the closest-lattice-point problem with Babai rounding
(round the coordinates of G^{-1} x); decoding is the exact mat-vec G z.
With a b-bit budget per weight the integer coordinates are clipped to the
signed range [-2^{b-1}, 2^{b-1}-1], so storage is exactly b bits/coordinate.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "int_range",
    "babai_round",
    "babai_decode",
    "init_generation_matrix",
    "spectral_clip",
    "lll_reduce",
    "gram_schmidt_norms",
    "babai_error_bound",
]


def int_range(bits: int) -> Tuple[int, int]:
    """Signed integer range for ``bits``-bit lattice coordinates."""
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    if bits == 1:  # binary lattice: use {-1, 0}? prefer symmetric {-1, 1}->{-1,0}
        lo, hi = -1, 0
    return lo, hi


def babai_round(g_inv: jax.Array, x: jax.Array, bits: int) -> jax.Array:
    """Babai rounding: z = clip(round(G^{-1} x)).

    Args:
      g_inv: [d, d] inverse generation matrix.
      x:     [d, ...] target vectors (d leading).
      bits:  clip range per coordinate.
    Returns integer codes with the same shape as ``x`` (int32).
    """
    lo, hi = int_range(bits)
    coords = jnp.tensordot(g_inv, x, axes=[[1], [0]])
    z = jnp.clip(jnp.round(coords), lo, hi)
    return z.astype(jnp.int32)


def babai_decode(g: jax.Array, z: jax.Array) -> jax.Array:
    """Decode lattice points: x_hat = G z.  z: [d, ...]."""
    return jnp.tensordot(g, z.astype(g.dtype), axes=[[1], [0]])


def init_generation_matrix(
    vectors: jax.Array,
    bits: int,
    *,
    eps: float = 1e-6,
    coverage_quantile: float = 0.999,
) -> jax.Array:
    """Paper init: Cholesky of the group's d x d covariance, scaled so that
    Babai coordinates of the data fill the 2^bits range.

    Args:
      vectors: [d, L] the group's (companded, normalized) sub-vectors.
      bits: target bit-width of the group.
    Returns G0 [d, d].
    """
    d = vectors.shape[0]
    cov = vectors @ vectors.T / max(vectors.shape[1], 1)
    cov = cov + eps * jnp.eye(d, dtype=vectors.dtype)
    chol = jnp.linalg.cholesky(cov)
    # Scale so that round(G^{-1} w) lands inside the clip range for
    # ``coverage_quantile`` of the data.
    coords = jax.scipy.linalg.solve_triangular(chol, vectors, lower=True)
    _, hi = int_range(bits)
    mag = jnp.quantile(jnp.abs(coords), coverage_quantile)
    scale = mag / max(hi + 0.5, 0.5)
    scale = jnp.maximum(scale, eps)
    return chol * scale


def spectral_clip(g: jax.Array, sigma_min: float, sigma_max: float) -> jax.Array:
    """Clip the singular values of G into [sigma_min, sigma_max]."""
    u, s, vt = jnp.linalg.svd(g, full_matrices=False)
    s = jnp.clip(s, sigma_min, sigma_max)
    return (u * s[..., None, :]) @ vt


def gram_schmidt_norms(basis: np.ndarray) -> np.ndarray:
    """Norms of the Gram-Schmidt orthogonalization of the basis columns."""
    b = np.asarray(basis, dtype=np.float64)
    d = b.shape[1]
    ortho = np.zeros_like(b)
    for i in range(d):
        v = b[:, i].copy()
        for j in range(i):
            denom = ortho[:, j] @ ortho[:, j]
            if denom > 0:
                v -= (b[:, i] @ ortho[:, j]) / denom * ortho[:, j]
        ortho[:, i] = v
    return np.linalg.norm(ortho, axis=0)


def _mu_coeffs(basis: np.ndarray) -> np.ndarray:
    """Gram-Schmidt projection coefficients mu[j, i] = <b_i, b*_j>/||b*_j||^2."""
    b = np.asarray(basis, dtype=np.float64)
    d = b.shape[1]
    ortho = np.zeros_like(b)
    mu = np.zeros((d, d))
    for i in range(d):
        v = b[:, i].copy()
        for j in range(i):
            denom = ortho[:, j] @ ortho[:, j]
            c = (b[:, i] @ ortho[:, j]) / denom if denom > 0 else 0.0
            mu[j, i] = c
            v -= c * ortho[:, j]
        ortho[:, i] = v
    return mu


def babai_error_bound(basis: np.ndarray) -> float:
    """Appendix A bound:  ||e|| <= 1/2 sqrt( sum_j (1 + sum_{i>j}|mu_ji|)^2 ||b*_j||^2 ).

    Valid for ANY basis (the LLL-reduced case specializes |mu| <= 1/2).
    """
    norms = gram_schmidt_norms(basis)
    mu = _mu_coeffs(basis)
    d = len(norms)
    total = 0.0
    for j in range(d):
        alpha = 0.5 * (1.0 + np.abs(mu[j, j + 1 :]).sum())
        total += (alpha ** 2) * norms[j] ** 2
    return float(np.sqrt(total))


def lll_reduce(basis: np.ndarray, delta: float = 0.75, max_iters: int = 10_000) -> np.ndarray:
    """LLL lattice-basis reduction (numpy, offline).  Columns are basis vectors.

    Used offline to precondition learned generation matrices so that Babai
    rounding's error bound (Appendix A) tightens; the lattice itself is
    unchanged (unimodular transform).
    """
    b = np.asarray(basis, dtype=np.float64).copy()
    n = b.shape[1]

    def gso(b):
        ortho = np.zeros_like(b)
        mu = np.zeros((n, n))
        for i in range(n):
            v = b[:, i].copy()
            for j in range(i):
                denom = ortho[:, j] @ ortho[:, j]
                mu[i, j] = (b[:, i] @ ortho[:, j]) / denom if denom > 0 else 0.0
                v -= mu[i, j] * ortho[:, j]
            ortho[:, i] = v
        return ortho, mu

    ortho, mu = gso(b)
    k, iters = 1, 0
    while k < n and iters < max_iters:
        iters += 1
        for j in range(k - 1, -1, -1):
            if abs(mu[k, j]) > 0.5:
                b[:, k] -= round(mu[k, j]) * b[:, j]
                ortho, mu = gso(b)
        nk = ortho[:, k] @ ortho[:, k]
        nk1 = ortho[:, k - 1] @ ortho[:, k - 1]
        if nk >= (delta - mu[k, k - 1] ** 2) * nk1:
            k += 1
        else:
            b[:, [k, k - 1]] = b[:, [k - 1, k]]
            ortho, mu = gso(b)
            k = max(k - 1, 1)
    return b
