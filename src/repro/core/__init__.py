"""Paper core: Grouped Lattice Vector Quantization (GLVQ)."""
from repro.core.glvq import GLVQConfig, quantize_group, quantize_layer, dequantize_layer
from repro.core.sdba import sdba, allocate_bits, group_salience, fractional_bits
from repro.core import lattice, companding, packing, baselines, quantized
from repro.core import qtensor
from repro.core.qtensor import QuantTensor

__all__ = [
    "GLVQConfig", "quantize_group", "quantize_layer", "dequantize_layer",
    "sdba", "allocate_bits", "group_salience", "fractional_bits",
    "lattice", "companding", "packing", "baselines", "quantized",
    "qtensor", "QuantTensor",
]
