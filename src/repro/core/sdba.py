"""Salience-Determined Bit Allocation (SDBA, Slim-LLM) — paper Sec. 3.1.

Solves  argmin_{b_1..b_G}  sum_g D_g(b_g)
subject to  b_g in {N-1, N, N+1},  mean(b) = N,  |G_{N+1}| = |G_{N-1}|
via the double-pointer search over salience-sorted groups: pair the i-th most
salient group (upgrade to N+1) with the i-th least salient (downgrade to N-1)
while the upgrade's distortion saving exceeds the downgrade's penalty.

Salience uses the calibration second moment: s_g = sum_{k in g} H_kk ||W_k||^2
(diagonal-Hessian importance, the standard Slim-LLM/GPTQ proxy); the
distortion model is the rate-distortion law  D_g(b) = s_g * var_g * 2^{-2b}.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["group_salience", "allocate_bits", "sdba"]


def group_salience(w: jax.Array, h: Optional[jax.Array], group_size: int) -> jax.Array:
    """Per-group salience s_g.  w: [K, N], h: [K, K] or None."""
    k = w.shape[0]
    n_g = k // group_size
    row_energy = jnp.sum(w.astype(jnp.float32) ** 2, axis=1)          # [K]
    if h is not None:
        row_energy = row_energy * jnp.diagonal(h).astype(jnp.float32)
    return row_energy.reshape(n_g, group_size).sum(axis=1)


def _group_var(w: jax.Array, group_size: int) -> jax.Array:
    k = w.shape[0]
    n_g = k // group_size
    return jnp.var(w.astype(jnp.float32).reshape(n_g, group_size * w.shape[1]), axis=1)


def allocate_bits(salience: np.ndarray, var: np.ndarray, n_bits: int) -> np.ndarray:
    """Double-pointer balanced allocation. Returns per-group bits (np.int32).

    Upgrade saving  (N -> N+1):  (3/4) q_g 2^{-2N}
    Downgrade cost  (N -> N-1):   3    q_g 2^{-2N}
    with q_g = s_g * var_g; pair while q_top > 4 * q_bot. The pointer walk is
    monotone -> O(G) after the sort (Slim-LLM's O(log m) binary search finds
    the same crossover; we keep the exact scan since G is small).
    """
    q = np.asarray(salience, np.float64) * np.asarray(var, np.float64)
    g = len(q)
    order = np.argsort(-q)  # descending
    bits = np.full(g, n_bits, np.int32)
    if n_bits <= 1:
        # can't downgrade below 1 bit; keep uniform
        return bits
    max_pairs = g // 2
    top, bot = 0, g - 1
    k = 0
    while k < max_pairs and q[order[top]] > 4.0 * q[order[bot]]:
        bits[order[top]] = n_bits + 1
        bits[order[bot]] = n_bits - 1
        top += 1
        bot -= 1
        k += 1
    return bits


def sdba(w: jax.Array, h: Optional[jax.Array], group_size: int, n_bits: int) -> np.ndarray:
    """Full SDBA for one layer: salience + variance -> balanced bit vector."""
    s = np.asarray(group_salience(w, h, group_size))
    v = np.asarray(_group_var(w, group_size))
    return allocate_bits(s, v, n_bits)


def fractional_bits(salience: np.ndarray, var: np.ndarray, target: float,
                    lo: int = 1, hi: int = 8) -> np.ndarray:
    """Fractional average rates (paper Sec 4.3): mix integer bit-widths so the
    arithmetic mean hits ``target`` exactly, preferring high-salience groups
    for the higher width."""
    base = int(np.floor(target))
    frac = target - base
    g = len(salience)
    n_hi = int(round(frac * g))
    q = np.asarray(salience, np.float64) * np.asarray(var, np.float64)
    order = np.argsort(-q)
    bits = np.full(g, base, np.int32)
    bits[order[:n_hi]] = min(base + 1, hi)
    bits = np.clip(bits, lo, hi)
    return bits
