"""GLVQ: grouped lattice vector quantization (paper Alg. 1).

Layout convention (shared with the Pallas kernels):
  * A linear layer weight is W [K, N] with y = x @ W (K = in, N = out).
  * Groups are ``group_size`` consecutive INPUT channels (rows of W) — the
    paper's "column groups" of the [out, in] matrix.
  * Within a group, lattice vectors of length d run along the OUTPUT dim:
    W[k, n0:n0+d] is one lattice vector. This makes runtime decoding of a
    [group_size, Nb] tile a single (group_size*Nb/d, d) @ (d, d) matmul.

Per group we learn (G_g, mu_g) by alternating Babai rounding (codes are
treated as constants, refreshed every iteration) with Adam steps on the
calibration-aware reconstruction loss

    L_g = || (W_g - What_g)^T X_g ||_F^2  + lam * ||G_g - G0_g||_F^2
        = tr(Dw^T H_g Dw) + lam ||G - G0||^2,    H_g = X_g X_g^T,

followed by spectral clipping of G and projection of mu to [10, 255].
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import companding, lattice

__all__ = ["GLVQConfig", "GroupQuant", "quantize_group", "quantize_layer", "dequantize_layer"]


@dataclasses.dataclass(frozen=True)
class GLVQConfig:
    d: int = 16                    # lattice dimension
    group_size: int = 128          # input channels per group (paper default)
    bits: int = 4                  # target average bit-width N
    iters: int = 100               # alternating-optimization steps
    lr: float = 7e-3               # Adam lr on (G, mu)
    lam: float = 0.1               # Frobenius anchor (paper Eq. 8)
    use_companding: bool = True    # group-specific mu-law (ablation: False)
    learn_lattice: bool = True     # ablation: fixed shared lattice if False
    bit_allocation: bool = True    # SDBA (GLVQ) vs uniform (GLVQ-u)
    rounding: str = "babai"        # "babai" | "gcd" (ablation)
    gcd_sweeps: int = 2
    sigma_lo: float = 0.25         # spectral clip, relative to G0's sigmas
    sigma_hi: float = 4.0
    fixed_mu: float = 50.0         # used when use_companding=False
    adam_b1: float = 0.9
    adam_b2: float = 0.99
    adam_eps: float = 1e-8


class GroupQuant(dict):
    """Pytree of stacked per-group results (plain dict for jax friendliness).

    keys: codes [n_g, gs, N] int32, g [n_g, d, d] f32, mu [n_g] f32,
          scale [n_g] f32, bits [n_g] int32.
    """


def _to_vectors(y: jax.Array, d: int) -> jax.Array:
    """[gs, N] -> [d, gs*N/d] with vectors along the output dim."""
    gs, n = y.shape
    return y.reshape(gs, n // d, d).transpose(2, 0, 1).reshape(d, gs * n // d)


def _from_vectors(v: jax.Array, gs: int, n: int) -> jax.Array:
    d = v.shape[0]
    return v.reshape(d, gs, n // d).transpose(1, 2, 0).reshape(gs, n)


def _clip_range(bits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Traced version of lattice.int_range (bits may be a per-group tracer)."""
    bits = jnp.asarray(bits, jnp.float32)
    lo = -jnp.exp2(bits - 1.0)
    hi = jnp.exp2(bits - 1.0) - 1.0
    # bits == 1 -> {-1, 0}: the generic formula already gives (-1, 0).
    return lo, hi


def _round_codes(g: jax.Array, y_vec: jax.Array, bits: jax.Array, cfg: GLVQConfig) -> jax.Array:
    lo, hi = _clip_range(bits)
    g_inv = jnp.linalg.inv(g)
    z = jnp.clip(jnp.round(g_inv @ y_vec), lo, hi)
    if cfg.rounding == "gcd":
        z = _gcd_refine(g, y_vec, z, lo, hi, cfg.gcd_sweeps)
    return z


def _gcd_refine(g, y, z, lo, hi, sweeps):
    """Greedy coordinate descent on ||y - G z||^2 (ablation baseline)."""
    gram_diag = jnp.sum(g * g, axis=0)  # ||g_i||^2

    def body(_, z):
        def coord(i, z):
            r = y - g @ z                      # residual
            gi = g[:, i]
            delta = (gi @ r) / (gram_diag[i] + 1e-12)
            zi = jnp.clip(jnp.round(z[i] + delta), lo, hi)
            return z.at[i].set(zi)
        return jax.lax.fori_loop(0, z.shape[0], coord, z)

    return jax.lax.fori_loop(0, sweeps, body, z)


def _reconstruct(g, z, mu, scale, gs, n, cfg: GLVQConfig) -> jax.Array:
    yq = g @ z
    w_hat_n = _from_vectors(yq, gs, n)
    w_hat_n = companding.expand(w_hat_n, mu) if cfg.use_companding else \
        companding.expand(w_hat_n, jnp.asarray(cfg.fixed_mu))
    return w_hat_n * scale


def quantize_group(
    w: jax.Array,                  # [gs, N]
    h: Optional[jax.Array],        # [gs, gs] = X_g X_g^T, or None (proxy: I)
    bits: jax.Array,               # scalar int32
    cfg: GLVQConfig,
    g_init: Optional[jax.Array] = None,   # override (fixed-lattice ablation)
):
    """Run Alg. 1 on one group. Returns dict(codes, g, mu, scale, w_hat)."""
    gs, n = w.shape
    d = cfg.d
    w = w.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    wn = w / scale

    if h is None:
        h_sel = jnp.eye(gs, dtype=jnp.float32)
    else:
        h_sel = h.astype(jnp.float32)

    def _g0_for(y0):
        v0 = _to_vectors(y0, d)
        g0 = lattice.init_generation_matrix(v0, int(cfg.bits))
        # coverage rescale for the group's actual bit-width (traced-safe):
        # init used cfg.bits; correct the radial scale by 2^(cfg.bits - bits).
        return g0 * jnp.exp2(jnp.asarray(cfg.bits, jnp.float32)
                             - jnp.asarray(bits, jnp.float32))

    def _init_err(mu_c):
        y0 = companding.compand(wn, mu_c)
        g0 = _g0_for(y0)
        z = _round_codes(g0, _to_vectors(y0, d), bits, cfg)
        w_hat = _reconstruct(g0, z, mu_c, scale, gs, n, cfg)
        dw = w - w_hat
        return jnp.sum((h_sel @ dw) * dw)

    if cfg.use_companding:
        # robust init: kurtosis-based mu (paper Eq. 12) can land poorly on
        # light-tailed groups; pick the best of three candidates by the
        # actual H-weighted reconstruction error at init.
        cands = jnp.stack([companding.init_mu(wn),
                           jnp.asarray(20.0, jnp.float32),
                           jnp.asarray(80.0, jnp.float32)])
        errs = jnp.stack([_init_err(c) for c in cands])
        mu0 = cands[jnp.argmin(errs)]
    else:
        mu0 = jnp.asarray(cfg.fixed_mu, jnp.float32)

    y0 = companding.compand(wn, mu0) if cfg.use_companding else \
        companding.compand(wn, jnp.asarray(cfg.fixed_mu))
    if g_init is None:
        g0 = _g0_for(y0)
    else:
        g0 = g_init
    s0 = jnp.linalg.svd(g0, compute_uv=False)
    sig_lo, sig_hi = cfg.sigma_lo * s0[-1], cfg.sigma_hi * s0[0]

    if h is None:
        h = jnp.eye(gs, dtype=jnp.float32)
    h = h.astype(jnp.float32)
    # normalize H so the loss scale (and lr) is layer-size independent
    h = h / (jnp.trace(h) / gs + 1e-12)

    def loss_fn(g, mu):
        mu_eff = mu if cfg.use_companding else jnp.asarray(cfg.fixed_mu)
        y = companding.compand(wn, mu_eff)
        z = jax.lax.stop_gradient(_round_codes(g, _to_vectors(y, d), bits, cfg))
        w_hat = _reconstruct(g, z, mu, scale, gs, n, cfg)
        dw = w - w_hat
        rec = jnp.sum((h @ dw) * dw)
        reg = cfg.lam * jnp.sum((g - g0) ** 2)
        return rec + reg

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1))

    def step(carry, _):
        g, mu, m, v, t, best = carry
        loss, (gg, gmu) = grad_fn(g, mu)
        # keep the best-seen (G, mu): the alternating loop is not monotone
        # because Z is refreshed every iteration.
        best_loss, best_g, best_mu = best
        better = loss < best_loss
        best = (jnp.where(better, loss, best_loss),
                jnp.where(better, g, best_g),
                jnp.where(better, mu, best_mu))
        if not cfg.learn_lattice:
            gg = jnp.zeros_like(gg)
        if not cfg.use_companding:
            gmu = jnp.zeros_like(gmu)
        grads = (gg, gmu)
        t = t + 1.0
        lr = cfg.lr
        m = jax.tree.map(lambda a, b: cfg.adam_b1 * a + (1 - cfg.adam_b1) * b, m, grads)
        v = jax.tree.map(lambda a, b: cfg.adam_b2 * a + (1 - cfg.adam_b2) * b * b, v, grads)
        mhat = jax.tree.map(lambda a: a / (1 - cfg.adam_b1 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - cfg.adam_b2 ** t), v)
        upd = jax.tree.map(lambda a, b: lr * a / (jnp.sqrt(b) + cfg.adam_eps), mhat, vhat)
        g = g - upd[0]
        mu = mu - upd[1] * 100.0   # mu lives on a [10, 255] scale
        g = lattice.spectral_clip(g, sig_lo, sig_hi)
        mu = companding.project_mu(mu)
        return (g, mu, m, v, t, best), None

    zeros = (jnp.zeros_like(g0), jnp.zeros_like(mu0))
    init = (g0, mu0, zeros, zeros, jnp.asarray(0.0),
            (jnp.asarray(jnp.inf), g0, mu0))
    (g_last, mu_last, _, _, _, best), _ = jax.lax.scan(
        step, init, None, length=cfg.iters)
    # final candidates: best-seen vs last iterate
    last_loss = loss_fn(g_last, mu_last)
    take_last = last_loss < best[0]
    g = jnp.where(take_last, g_last, best[1])
    mu = jnp.where(take_last, mu_last, best[2])

    mu_eff = mu if cfg.use_companding else jnp.asarray(cfg.fixed_mu)
    y = companding.compand(wn, mu_eff)
    z = _round_codes(g, _to_vectors(y, d), bits, cfg)
    w_hat = _reconstruct(g, z, mu, scale, gs, n, cfg)
    codes = _from_vectors(z, gs, n).astype(jnp.int32)
    return dict(codes=codes, g=g, mu=mu, scale=scale, w_hat=w_hat)


@functools.partial(jax.jit, static_argnames=("cfg", "has_h"))
def _quantize_layer_jit(w_groups, h_groups, bits, cfg: GLVQConfig, has_h: bool):
    fn = lambda wg, hg, b: quantize_group(wg, hg if has_h else None, b, cfg)
    return jax.vmap(fn)(w_groups, h_groups, bits)


def quantize_layer(
    w: jax.Array,                       # [K, N]
    h: Optional[jax.Array],             # [K, K] calibration second moment
    cfg: GLVQConfig,
    bits_per_group: Optional[jax.Array] = None,
) -> GroupQuant:
    """Quantize a full layer; vmaps Alg. 1 over the K/group_size groups."""
    k, n = w.shape
    gs = cfg.group_size
    if k % gs:
        raise ValueError(f"K={k} not divisible by group_size={gs}")
    if n % cfg.d:
        raise ValueError(f"N={n} not divisible by lattice dim d={cfg.d}")
    n_g = k // gs
    w_groups = w.reshape(n_g, gs, n)
    if h is not None:
        hb = h.reshape(n_g, gs, n_g, gs)
        h_groups = jnp.stack([hb[i, :, i, :] for i in range(n_g)])
    else:
        h_groups = jnp.zeros((n_g, gs, gs), w.dtype)
    if bits_per_group is None:
        bits_per_group = jnp.full((n_g,), cfg.bits, jnp.int32)
    out = _quantize_layer_jit(w_groups, h_groups, bits_per_group, cfg, h is not None)
    out["bits"] = bits_per_group
    return GroupQuant(out)


def dequantize_layer(q: GroupQuant, cfg: GLVQConfig) -> jax.Array:
    """Reference decode: [n_g, gs, N] codes -> [K, N] weights."""
    def dec(codes, g, mu, scale):
        gs, n = codes.shape
        z = _to_vectors(codes.astype(jnp.float32), cfg.d)
        return _reconstruct(g, z, mu, scale, gs, n, cfg)
    w_groups = jax.vmap(dec)(q["codes"], q["g"], q["mu"], q["scale"])
    n_g, gs, n = w_groups.shape
    return w_groups.reshape(n_g * gs, n)
