"""Paged KV-cache kernels: per-token quantized append + blockwise gather.

The serving cache is a pool of fixed-size blocks ([num_blocks, block_size,
KV, hd] per attention layer); a per-slot block table maps logical positions
to pool blocks (``serving.kvcache`` owns the allocator / table bookkeeping).
This module owns the two device operations on that layout:

  * ``append``       — write one token's K/V (quantized per cache mode) into
    each slot's current block at its current offset.
  * ``append_chunk`` — write a whole chunk of T tokens per slot in one call
    (the chunked-prefill path: whole blocks land per step instead of one
    token at a time); invalid slab positions are masked out.
  * ``gather``       — read a slot's blocks back in logical order and
    dequantize them into dense [B, S, KV, hd] history for attention.

Cache modes (``MODES``):
  * ``paged``     — blocks store the raw compute dtype (paging only).
  * ``paged_q8``  — int8 codes + per-token-per-head f16 max-abs scale.
  * ``paged_q8c`` — int8 after mu-law companding (``core.companding`` with a
    fixed mu, ``KV_MU``): the code grid concentrates near zero where K/V mass
    lives, trading headroom at the tails — the paper's GLVQ companding applied
    to the serving cache.

Backends mirror the ``kernels.ops`` matmul registry: ``pallas`` (scalar-
prefetch block scatter/gather, fused dequant in VMEM; interpret-mode off-TPU)
and ``xla`` (pure-jnp scatter/take fallback).  Selection: explicit arg >
``REPRO_KV_BACKEND`` env > platform default (pallas on TPU, xla elsewhere).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import companding

__all__ = ["MODES", "KV_MU", "PageLayout", "kv_quantize", "kv_dequantize",
           "chunk_roundtrip", "tile_pad_enabled", "padded_block_geom",
           "pad_to", "register_kv_backend", "kv_backends",
           "resolve_kv_backend", "pool_init", "copy_pool_block", "append",
           "append_chunk", "gather"]

MODES = ("paged", "paged_q8", "paged_q8c")

# Fixed companding strength for the paged_q8c mode. K/V activations are far
# less heavy-tailed than weights, so a mild mu suffices; per-block learned mu
# would double the side-information for little gain at 8 bits.
KV_MU = 15.0

_ENV_BACKEND = "REPRO_KV_BACKEND"
_ENV_FORCE_PAD = "REPRO_KV_FORCE_TILE_PAD"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tile_pad_enabled() -> bool:
    """Should Pallas block shapes be padded to Mosaic tile boundaries?

    The Mosaic validator rejects VMEM blocks whose trailing dims aren't
    tile-aligned ((8, 128) for f32); interpret mode doesn't care.  Padding
    therefore engages on TPU (where aligned geometries skip it entirely —
    no copies) and via ``REPRO_KV_FORCE_TILE_PAD=1`` so CPU tests can
    exercise the pad path."""
    return _on_tpu() or os.environ.get(_ENV_FORCE_PAD, "") not in ("", "0")


def padded_block_geom(block_size: int, hd: int) -> Tuple[int, int]:
    """Tile-aligned (block_size, hd) a padded pool block uses: the token dim
    rounds up to the f32 sublane count (8), the head dim to the lane count
    (128)."""
    return -(-block_size // 8) * 8, -(-hd // 128) * 128


def pad_to(x, axis: int, mult: int):
    """Zero-pad ``x`` so ``shape[axis]`` becomes a multiple of ``mult``
    (identity when already aligned — no copy)."""
    short = -x.shape[axis] % mult
    if short == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, short)
    return jnp.pad(x, widths)


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Static pool/table geometry shared by every consumer of the paged
    cache — the single place the sizing rule lives (``models.lm`` builds
    pools from it, ``serving.kvcache`` allocates against it)."""
    block_size: int
    blocks_per_slot: int          # table width: ceil(s_cache / block_size)
    num_blocks: int               # pool depth, incl. the scratch block 0

    @classmethod
    def plan(cls, s_cache: int, slots: int, block_size: int = 16,
             num_blocks: Optional[int] = None) -> "PageLayout":
        bps = -(-s_cache // block_size)
        if num_blocks is None:
            num_blocks = 1 + slots * bps        # worst case: every slot full
        if num_blocks < 2:
            raise ValueError("paged cache needs >= 2 blocks "
                             "(block 0 is reserved scratch)")
        return cls(block_size=block_size, blocks_per_slot=bps,
                   num_blocks=num_blocks)


# ---------------------------------------------------------------------------
# quantize / dequantize (shared by both backends)
# ---------------------------------------------------------------------------

def kv_quantize(x, mode: str) -> Tuple[jax.Array, jax.Array]:
    """x [..., KV, hd] -> (int8 codes [..., KV, hd], f16 amax [..., KV])."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-6)
    u = x / amax[..., None]
    if mode == "paged_q8c":
        u = companding.compand(u.astype(jnp.float32), KV_MU)
    codes = jnp.clip(jnp.round(u.astype(jnp.float32) * 127.0), -127, 127)
    return codes.astype(jnp.int8), amax.astype(jnp.float16)


def kv_dequantize(codes, amax, mode: str, dtype) -> jax.Array:
    """(int8 codes [..., KV, hd], f16 amax [..., KV]) -> values [..., KV, hd]."""
    u = codes.astype(jnp.float32) / 127.0
    if mode == "paged_q8c":
        u = companding.expand(u, KV_MU)
    return (u * amax.astype(jnp.float32)[..., None]).astype(dtype)


def chunk_roundtrip(k, v, *, mode: str, store_dtype,
                    out_dtype) -> Tuple[jax.Array, jax.Array]:
    """Roundtrip a chunk's in-flight K/V through the cache codec.

    Sliding-window chunk attention reads the chunk's own keys before they
    land in the pools, so they must read back exactly what a later gather
    would return.  For the quantized kinds that is quantize -> dequantize;
    for ``paged`` the codec is a dtype cast — and when the pool stores the
    compute dtype already, an identity (the arrays are returned untouched,
    no casts)."""
    if mode == "paged":
        if jnp.dtype(store_dtype) == jnp.dtype(out_dtype):
            return k, v
        return (k.astype(store_dtype).astype(out_dtype),
                v.astype(store_dtype).astype(out_dtype))
    return (kv_dequantize(*kv_quantize(k, mode), mode, out_dtype),
            kv_dequantize(*kv_quantize(v, mode), mode, out_dtype))


def pool_init(num_blocks: int, block_size: int, n_kv: int, hd: int, dtype,
              mode: str) -> Dict[str, jax.Array]:
    """Per-layer pool leaves.  ``kp``/``vp`` are the K/V blocks; quantized
    modes add per-token-per-head scales ``ksc``/``vsc``."""
    if mode not in MODES:
        raise ValueError(f"unknown cache mode {mode!r}; available: {MODES}")
    store = dtype if mode == "paged" else jnp.int8
    pools = dict(
        kp=jnp.zeros((num_blocks, block_size, n_kv, hd), store),
        vp=jnp.zeros((num_blocks, block_size, n_kv, hd), store),
    )
    if mode != "paged":
        pools["ksc"] = jnp.zeros((num_blocks, block_size, n_kv), jnp.float16)
        pools["vsc"] = jnp.zeros((num_blocks, block_size, n_kv), jnp.float16)
    return pools


def copy_pool_block(pool, src, dst, *, stacked: bool = False):
    """Duplicate one pool block's stored content: ``pool[dst] = pool[src]``
    (codes AND scales copy verbatim, so the clone dequantizes bit-identically
    to the original — the copy-on-write primitive behind prefix sharing).
    ``src``/``dst`` may be traced int scalars; ``stacked`` marks a leading
    scan-repeat axis ([R, NB, ...] — every repeat's layer copies the same
    block id, matching the shared block table)."""
    if stacked:
        return pool.at[:, dst].set(pool[:, src])
    return pool.at[dst].set(pool[src])


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_KV_BACKENDS: Dict[str, type] = {}


def register_kv_backend(name: str):
    """Decorator: register a namespace with ``append``/``gather`` staticmethods."""
    def deco(obj):
        _KV_BACKENDS[name] = obj
        return obj
    return deco


def kv_backends() -> Tuple[str, ...]:
    return tuple(sorted(_KV_BACKENDS))


def resolve_kv_backend(backend: Optional[str] = None) -> str:
    """explicit arg > REPRO_KV_BACKEND env > platform default."""
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND, "").strip() or None
    if backend is None:
        return "pallas" if _on_tpu() else "xla"
    if backend not in _KV_BACKENDS:
        raise ValueError(f"unknown kv backend {backend!r}; "
                         f"available: {kv_backends()}")
    return backend


# ---------------------------------------------------------------------------
# XLA fallback backend
# ---------------------------------------------------------------------------

@register_kv_backend("xla")
class _XlaKV:
    @staticmethod
    def append(cache, kq, vq, ks, vs, bids, offs):
        new = dict(cache)
        new["kp"] = cache["kp"].at[bids, offs].set(kq)
        new["vp"] = cache["vp"].at[bids, offs].set(vq)
        if ks is not None:
            new["ksc"] = cache["ksc"].at[bids, offs].set(ks)
            new["vsc"] = cache["vsc"].at[bids, offs].set(vs)
        return new

    @staticmethod
    def append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids):
        # bids/offs [B, T]; masked tokens arrive with bids == num_blocks,
        # which the drop-mode scatter discards.  prog_bids is the Pallas
        # backend's per-slot touched-block list — unused here.
        new = dict(cache)
        new["kp"] = cache["kp"].at[bids, offs].set(kq, mode="drop")
        new["vp"] = cache["vp"].at[bids, offs].set(vq, mode="drop")
        if ks is not None:
            new["ksc"] = cache["ksc"].at[bids, offs].set(ks, mode="drop")
            new["vsc"] = cache["vsc"].at[bids, offs].set(vs, mode="drop")
        return new

    @staticmethod
    def gather(cache, table, mode, out_dtype):
        b, nb = table.shape
        bs = cache["kp"].shape[1]
        flat = table.reshape(-1)

        def pull(pool):
            g = jnp.take(pool, flat, axis=0)          # [B*nb, bs, KV, hd]
            return g.reshape((b, nb * bs) + pool.shape[2:])

        kg, vg = pull(cache["kp"]), pull(cache["vp"])
        if mode == "paged":
            return kg.astype(out_dtype), vg.astype(out_dtype)
        ksc, vsc = pull(cache["ksc"]), pull(cache["vsc"])
        return (kv_dequantize(kg, ksc, mode, out_dtype),
                kv_dequantize(vg, vsc, mode, out_dtype))


# ---------------------------------------------------------------------------
# Pallas backend
# ---------------------------------------------------------------------------

def _pad_pool_leaf(name: str, arr):
    """Tile-align one pool leaf: token dim (1) to x8, head dim (kp/vp) to
    x128.  Offsets stay valid (< block_size) and gathered pad rows are
    sliced off before anything reads them."""
    if name in ("kp", "vp"):
        return pad_to(pad_to(arr, 1, 8), 3, 128)
    return pad_to(arr, 1, 8)


def _unpad_pool_leaf(name: str, arr, bs: int, hd: int):
    if name in ("kp", "vp"):
        return arr[:, :bs, :, :hd]
    return arr[:, :bs]


def _append_kernel(bids_ref, offs_ref, *refs, quant: bool):
    """Grid (B,): read-modify-write slot b's current block, one token row."""
    b = pl.program_id(0)
    o = offs_ref[b]
    n_arr = 4 if quant else 2
    news, ins, outs = refs[:n_arr], refs[n_arr:2 * n_arr], refs[2 * n_arr:]
    for new_ref, in_ref, out_ref in zip(news, ins, outs):
        out_ref[...] = in_ref[...]
        out_ref[0, o] = new_ref[0]


def _append_chunk_kernel(pbids_ref, bids_ref, offs_ref, *refs, quant: bool,
                         t: int, nb: int):
    """Grid (B, NB): read-modify-write pool block prog_bids[b, n], storing
    every slab token whose target block id matches it.  Masked tokens carry
    an out-of-pool sentinel bid and match no program."""
    b = pl.program_id(0)
    n = pl.program_id(1)
    mine = pbids_ref[b * nb + n]
    n_arr = 4 if quant else 2
    news, ins, outs = refs[:n_arr], refs[n_arr:2 * n_arr], refs[2 * n_arr:]
    for new_ref, in_ref, out_ref in zip(news, ins, outs):
        out_ref[...] = in_ref[...]
    for tok in range(t):
        @pl.when(bids_ref[b * t + tok] == mine)
        def _write(_tok=tok):
            o = offs_ref[b * t + _tok]
            for new_ref, out_ref in zip(news, outs):
                out_ref[0, o] = new_ref[0, _tok]


def _gather_kernel(tbl_ref, *refs, mode: str, out_dtype):
    """Grid (B, nb): dequantize pool block table[b, j] into out[b, j]."""
    if mode == "paged":
        kp, vp, gk, gv = refs
        gk[0, 0] = kp[0].astype(out_dtype)
        gv[0, 0] = vp[0].astype(out_dtype)
        return
    kp, ksc, vp, vsc, gk, gv = refs
    gk[0, 0] = kv_dequantize(kp[0], ksc[0], mode, out_dtype)
    gv[0, 0] = kv_dequantize(vp[0], vsc[0], mode, out_dtype)


@register_kv_backend("pallas")
class _PallasKV:
    @staticmethod
    def append(cache, kq, vq, ks, vs, bids, offs):
        quant = ks is not None
        news = (kq, vq, ks, vs) if quant else (kq, vq)
        pools = ("kp", "vp", "ksc", "vsc") if quant else ("kp", "vp")
        ins = tuple(cache[p] for p in pools)
        b = kq.shape[0]
        bs, _, hd = cache["kp"].shape[1:]
        padded = tile_pad_enabled() and padded_block_geom(bs, hd) != (bs, hd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
            news = tuple(pad_to(a, 2, 128) if a.ndim == 3 else a
                         for a in news)

        def tok_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, bids, offs, _nd=nd: (i,) + (0,) * _nd)

        def blk_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, bids, offs, _nd=nd:
                                (bids[i],) + (0,) * _nd)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b,),
            in_specs=[tok_spec(a) for a in news] + [blk_spec(a) for a in ins],
            out_specs=tuple(blk_spec(a) for a in ins),
        )
        # alias each pool input onto its output: in-place block update
        aliases = {2 + len(news) + i: i for i in range(len(ins))}
        outs = pl.pallas_call(
            functools.partial(_append_kernel, quant=quant),
            grid_spec=grid_spec,
            out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ins),
            input_output_aliases=aliases,
            interpret=not _on_tpu(),
        )(bids, offs, *news, *ins)
        if padded:
            outs = tuple(_unpad_pool_leaf(n, a, bs, hd)
                         for n, a in zip(pools, outs))
        new = dict(cache)
        new.update(dict(zip(pools, outs)))
        return new

    @staticmethod
    def append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids):
        quant = ks is not None
        news = (kq, vq, ks, vs) if quant else (kq, vq)
        pools = ("kp", "vp", "ksc", "vsc") if quant else ("kp", "vp")
        ins = tuple(cache[p] for p in pools)
        b, t = bids.shape
        nb = prog_bids.shape[1]
        bs, _, hd = cache["kp"].shape[1:]
        padded = tile_pad_enabled() and padded_block_geom(bs, hd) != (bs, hd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
            news = tuple(pad_to(a, 3, 128) if a.ndim == 4 else a
                         for a in news)

        def tok_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, j, pb, bd, of, _nd=nd:
                                (i,) + (0,) * _nd)

        def blk_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, j, pb, bd, of, _nd=nd:
                                (pb[i * nb + j],) + (0,) * _nd)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, nb),
            in_specs=[tok_spec(a) for a in news] + [blk_spec(a) for a in ins],
            out_specs=tuple(blk_spec(a) for a in ins),
        )
        aliases = {3 + len(news) + i: i for i in range(len(ins))}
        outs = pl.pallas_call(
            functools.partial(_append_chunk_kernel, quant=quant, t=t, nb=nb),
            grid_spec=grid_spec,
            out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ins),
            input_output_aliases=aliases,
            interpret=not _on_tpu(),
        )(prog_bids.reshape(-1), bids.reshape(-1), offs.reshape(-1), *news,
          *ins)
        if padded:
            outs = tuple(_unpad_pool_leaf(n, a, bs, hd)
                         for n, a in zip(pools, outs))
        new = dict(cache)
        new.update(dict(zip(pools, outs)))
        return new

    @staticmethod
    def gather(cache, table, mode, out_dtype):
        b, nb = table.shape
        bs, kv, hd = cache["kp"].shape[1:]
        quant = mode != "paged"
        pools = (("kp", "ksc", "vp", "vsc") if quant else ("kp", "vp"))
        ins = tuple(cache[p] for p in pools)
        padded = tile_pad_enabled() and padded_block_geom(bs, hd) != (bs, hd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
        bs_p, _, hd_p = ins[0].shape[1:]

        def pool_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec(
                (1,) + arr.shape[1:],
                lambda i, j, tbl, _nd=nd:
                (tbl[i * nb + j],) + (0,) * _nd)

        out_spec = pl.BlockSpec((1, 1, bs_p, kv, hd_p),
                                lambda i, j, tbl: (i, j, 0, 0, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nb),
            in_specs=[pool_spec(a) for a in ins],
            out_specs=(out_spec, out_spec),
        )
        out_sds = jax.ShapeDtypeStruct((b, nb, bs_p, kv, hd_p), out_dtype)
        gk, gv = pl.pallas_call(
            functools.partial(_gather_kernel, mode=mode, out_dtype=out_dtype),
            grid_spec=grid_spec,
            out_shape=(out_sds, out_sds),
            interpret=not _on_tpu(),
        )(table.reshape(-1), *ins)
        if padded:
            gk, gv = gk[:, :, :bs, :, :hd], gv[:, :, :bs, :, :hd]
        return gk.reshape(b, nb * bs, kv, hd), gv.reshape(b, nb * bs, kv, hd)


# ---------------------------------------------------------------------------
# Public entry points (mode-aware, backend-dispatched)
# ---------------------------------------------------------------------------

def append(cache: Dict[str, jax.Array], k_new, v_new, bids, offs, *,
           mode: str, backend: Optional[str] = None) -> Dict[str, jax.Array]:
    """Write one token per slot.  k_new/v_new [B, KV, hd]; bids/offs [B] int32
    (the slot's current block id / in-block offset).  Returns the new cache."""
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_append[{mode}]"):
        if mode == "paged":
            store = cache["kp"].dtype
            return be.append(cache, k_new.astype(store), v_new.astype(store),
                             None, None, bids, offs)
        kq, ks = kv_quantize(k_new, mode)
        vq, vs = kv_quantize(v_new, mode)
        return be.append(cache, kq, vq, ks, vs, bids, offs)


def append_chunk(cache: Dict[str, jax.Array], k_new, v_new, bids, offs,
                 valid, prog_bids, *, mode: str,
                 backend: Optional[str] = None) -> Dict[str, jax.Array]:
    """Write up to T tokens per slot in one call (chunked prefill).

    k_new/v_new [B, T, KV, hd]; bids/offs [B, T] int32 target block id /
    in-block offset per slab token; valid [B, T] bool masks pad positions
    (their writes are dropped).  ``prog_bids`` [B, NB] int32 lists the pool
    blocks each slot's chunk touches (entries must be distinct per slot or
    the scratch block 0) — the Pallas backend runs one grid program per
    (slot, touched block); the XLA backend scatters directly and ignores it.
    Returns the new cache."""
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    num_blocks = cache["kp"].shape[0]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_append_chunk[{mode}]"):
        bids = jnp.where(valid, bids, num_blocks).astype(jnp.int32)
        offs = offs.astype(jnp.int32)
        if mode == "paged":
            store = cache["kp"].dtype
            return be.append_chunk(cache, k_new.astype(store),
                                   v_new.astype(store), None, None, bids,
                                   offs, prog_bids)
        kq, ks = kv_quantize(k_new, mode)
        vq, vs = kv_quantize(v_new, mode)
        return be.append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids)


def gather(cache: Dict[str, jax.Array], table, *, mode: str,
           backend: Optional[str] = None,
           out_dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Read blocks ``table`` [B, nb] back as dense dequantized history:
    (k, v) each [B, nb * block_size, KV, hd] in logical token order."""
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_gather[{mode}]"):
        return be.gather(cache, table, mode, out_dtype)
