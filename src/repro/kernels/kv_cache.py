"""Paged KV-cache kernels: per-token quantized append + blockwise gather.

The serving cache is a pool of fixed-size blocks ([num_blocks, block_size,
KV, hd] per attention layer); a per-slot block table maps logical positions
to pool blocks (``serving.kvcache`` owns the allocator / table bookkeeping).
This module owns the two device operations on that layout:

  * ``append``       — write one token's K/V (quantized per cache mode) into
    each slot's current block at its current offset.
  * ``append_chunk`` — write a whole chunk of T tokens per slot in one call
    (the chunked-prefill path: whole blocks land per step instead of one
    token at a time); invalid slab positions are masked out.
  * ``gather``       — read a slot's blocks back in logical order and
    dequantize them into dense [B, S, KV, hd] history for attention.

Cache modes (``MODES``):
  * ``paged``      — blocks store the raw compute dtype (paging only).
  * ``paged_q8``   — int8 codes + per-token-per-head f16 max-abs scale.
  * ``paged_q8c``  — int8 after mu-law companding (``core.companding`` with a
    fixed mu, ``KV_MU``): the code grid concentrates near zero where K/V mass
    lives, trading headroom at the tails — the paper's GLVQ companding applied
    to the serving cache.
  * ``paged_glvq`` — the paper's grouped lattice vector quantizer applied to
    K/V activations: each head-dim vector splits into d-dim sub-vectors,
    Babai-rounded against a per-head learned generation matrix
    (``core.lattice``), the b-bit integer coordinates word-packed
    (``core.packing``) into uint32 pool blocks.  Per-head codebooks
    (G / G^-1 / mu) live as extra pool leaves; the default (uncalibrated)
    codebook is the identity lattice, which makes ``paged_glvq`` exactly
    uniform signed-b-bit quantization — the baseline the calibrated
    codebooks (``data.calibration.calibrate_kv``) must beat.

Backends mirror the ``kernels.ops`` matmul registry: ``pallas`` (scalar-
prefetch block scatter/gather, fused dequant in VMEM; interpret-mode off-TPU)
and ``xla`` (pure-jnp scatter/take fallback).  Selection: explicit arg >
``REPRO_KV_BACKEND`` env > platform default (pallas on TPU, xla elsewhere).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import companding, lattice, packing

__all__ = ["MODES", "INT8_MODES", "KV_MU", "PageLayout", "GLVQSpec",
           "default_glvq_spec", "glvq_default_book", "glvq_spec_from_pool",
           "glvq_quantize", "glvq_dequantize", "glvq_decode_head",
           "GLVQ_BOOK_LEAVES",
           "kv_quantize", "kv_dequantize",
           "chunk_roundtrip", "tile_pad_enabled", "padded_block_geom",
           "pad_to", "register_kv_backend", "kv_backends",
           "resolve_kv_backend", "pool_init", "copy_pool_block", "append",
           "append_chunk", "gather"]

MODES = ("paged", "paged_q8", "paged_q8c", "paged_glvq")
INT8_MODES = ("paged_q8", "paged_q8c")

# Fixed companding strength for the paged_q8c mode. K/V activations are far
# less heavy-tailed than weights, so a mild mu suffices; per-block learned mu
# would double the side-information for little gain at 8 bits.
KV_MU = 15.0

_ENV_BACKEND = "REPRO_KV_BACKEND"
_ENV_FORCE_PAD = "REPRO_KV_FORCE_TILE_PAD"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tile_pad_enabled() -> bool:
    """Should Pallas block shapes be padded to Mosaic tile boundaries?

    The Mosaic validator rejects VMEM blocks whose trailing dims aren't
    tile-aligned ((8, 128) for f32); interpret mode doesn't care.  Padding
    therefore engages on TPU (where aligned geometries skip it entirely —
    no copies) and via ``REPRO_KV_FORCE_TILE_PAD=1`` so CPU tests can
    exercise the pad path."""
    return _on_tpu() or os.environ.get(_ENV_FORCE_PAD, "") not in ("", "0")


def padded_block_geom(block_size: int, hd: int) -> Tuple[int, int]:
    """Tile-aligned (block_size, hd) a padded pool block uses: the token dim
    rounds up to the f32 sublane count (8), the head dim to the lane count
    (128)."""
    return -(-block_size // 8) * 8, -(-hd // 128) * 128


def pad_to(x, axis: int, mult: int):
    """Zero-pad ``x`` so ``shape[axis]`` becomes a multiple of ``mult``
    (identity when already aligned — no copy)."""
    short = -x.shape[axis] % mult
    if short == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, short)
    return jnp.pad(x, widths)


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Static pool/table geometry shared by every consumer of the paged
    cache — the single place the sizing rule lives (``models.lm`` builds
    pools from it, ``serving.kvcache`` allocates against it)."""
    block_size: int
    blocks_per_slot: int          # table width: ceil(s_cache / block_size)
    num_blocks: int               # pool depth, incl. the scratch block 0

    @classmethod
    def plan(cls, s_cache: int, slots: int, block_size: int = 16,
             num_blocks: Optional[int] = None) -> "PageLayout":
        bps = -(-s_cache // block_size)
        if num_blocks is None:
            num_blocks = 1 + slots * bps        # worst case: every slot full
        if num_blocks < 2:
            raise ValueError("paged cache needs >= 2 blocks "
                             "(block 0 is reserved scratch)")
        return cls(block_size=block_size, blocks_per_slot=bps,
                   num_blocks=num_blocks)


# ---------------------------------------------------------------------------
# GLVQ codec spec + codebooks (paged_glvq)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GLVQSpec:
    """Static geometry of the ``paged_glvq`` codec.

    ``bits`` / ``d`` / ``hd`` are NOT derivable from pool shapes (hd = 16
    packs to 2 words at bits = 3 AND bits = 4), so the spec threads
    statically from the ``EngineConfig`` down to the kernels.  Hashable, so
    it rides through ``functools.partial`` into Pallas kernels."""
    bits: int = 4                 # coordinate bit-width (word-packed)
    d: int = 4                    # lattice sub-vector length along hd
    hd: int = 128                 # head dim (d must divide it)

    def __post_init__(self):
        if not 2 <= self.bits <= 8:
            raise ValueError(f"GLVQSpec.bits must be in [2, 8], "
                             f"got {self.bits}")
        if self.d < 1 or self.hd % self.d:
            raise ValueError(f"lattice dim d={self.d} must divide head dim "
                             f"hd={self.hd}")

    @property
    def n_words(self) -> int:
        """uint32 words per head-dim vector (word padding included)."""
        return packing.packed_len(self.hd, self.bits)

    @property
    def n_vec(self) -> int:
        return self.hd // self.d

    @property
    def hi(self) -> int:
        return lattice.int_range(self.bits)[1]


def default_glvq_spec(hd: int, bits: int = 4,
                      d: Optional[int] = None) -> GLVQSpec:
    """Spec with the largest supported lattice dim dividing ``hd``."""
    if d is None:
        d = next((c for c in (4, 2) if hd % c == 0), 1)
    return GLVQSpec(bits=bits, d=d, hd=hd)


# codebook pool leaves: per-KV-head generation matrices + companding mu.
# kgi/vgi cache G^-1 so the encode path never inverts inside the step.
GLVQ_BOOK_LEAVES = ("kg", "kgi", "vg", "vgi", "kmu", "vmu")


def glvq_default_book(n_kv: int, spec: GLVQSpec) -> Dict[str, jax.Array]:
    """Identity-lattice codebook: G = I / hi, so Babai rounding degenerates
    to uniform signed-``bits``-bit quantization (mu <= 0 disables the
    companding).  This is both the uncalibrated fallback AND the uniform-int
    baseline calibrated codebooks are benchmarked against."""
    eye = jnp.broadcast_to(jnp.eye(spec.d, dtype=jnp.float32),
                           (n_kv, spec.d, spec.d))
    return dict(kg=eye / spec.hi, kgi=eye * spec.hi,
                vg=eye / spec.hi, vgi=eye * spec.hi,
                kmu=jnp.zeros((n_kv,), jnp.float32),
                vmu=jnp.zeros((n_kv,), jnp.float32))


def glvq_spec_from_pool(cache: Dict[str, jax.Array]) -> GLVQSpec:
    """Best-effort spec recovery for callers that did not thread one:
    assumes the default ``bits=4`` (whose 8-codes-per-word packing makes
    hd recoverable whenever ``hd % 8 == 0``).  Callers running bits != 4
    must pass their ``GLVQSpec`` explicitly."""
    d = cache["kg"].shape[-1]
    hd = cache["kp"].shape[-1] * packing.per_word(4)
    return GLVQSpec(bits=4, d=d, hd=hd)


def glvq_quantize(x, g_inv, mu, spec: GLVQSpec) -> Tuple[jax.Array, jax.Array]:
    """GLVQ encode: x [..., KV, hd] -> (uint32 words [..., KV, n_words],
    f16 amax [..., KV]).

    Per token-head: normalize by max-abs, mu-law compand (skipped while the
    head's mu <= 0 — the uncalibrated identity book), split hd into d-dim
    sub-vectors, Babai-round each against G^-1 (``lattice.babai_round``
    semantics: clip(round(G^-1 y))), word-pack the signed codes."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-6)
    u = (x / amax[..., None]).astype(jnp.float32)
    mu = mu.astype(jnp.float32)[..., None]                     # [KV, 1]
    y = jnp.where(mu > 0, companding.compand(u, jnp.maximum(mu, 1.0)), u)
    yv = y.reshape(y.shape[:-1] + (spec.n_vec, spec.d))
    z = jnp.einsum("kij,...kvj->...kvi", g_inv.astype(jnp.float32), yv)
    lo, hi = lattice.int_range(spec.bits)
    z = jnp.clip(jnp.round(z), lo, hi).astype(jnp.int32)
    codes = z.reshape(y.shape)                                 # [..., KV, hd]
    return packing.pack_codes(codes, spec.bits), amax.astype(jnp.float16)


def glvq_dequantize(words, amax, g, mu, spec: GLVQSpec, dtype) -> jax.Array:
    """GLVQ decode: (uint32 words [..., KV, n_words], f16 amax [..., KV])
    -> values [..., KV, hd].  Exact mat-vec ``G z`` per sub-vector
    (``lattice.babai_decode``), mu-law expand, rescale by amax."""
    codes = packing.unpack_codes(words, spec.bits, spec.hd)    # [..., KV, hd]
    zv = codes.astype(jnp.float32).reshape(
        codes.shape[:-1] + (spec.n_vec, spec.d))
    y = jnp.einsum("kij,...kvj->...kvi", g.astype(jnp.float32), zv)
    y = y.reshape(codes.shape)
    mu = mu.astype(jnp.float32)[..., None]                     # [KV, 1]
    u = jnp.where(mu > 0, companding.expand(y, jnp.maximum(mu, 1.0)), y)
    return (u * amax.astype(jnp.float32)[..., None]).astype(dtype)


def glvq_decode_head(words, amax, g, mu, spec: GLVQSpec, dtype,
                     hd_out: Optional[int] = None) -> jax.Array:
    """Single-head GLVQ decode, Pallas-friendly: one 2-D dot per call
    (no batched einsum, which Mosaic rejects).  words [n, >= n_words]
    uint32 (trailing pad words ignored), amax [n], g [d, d], mu scalar ->
    values [n, hd_out or hd] (extra columns zero-padded for tile-aligned
    out blocks)."""
    codes = packing.unpack_codes(words[:, :spec.n_words], spec.bits, spec.hd)
    z = codes.astype(jnp.float32).reshape(-1, spec.d)
    # rows of z @ G^T are G z — the exact lattice.babai_decode mat-vec
    y = jax.lax.dot_general(z, g.astype(jnp.float32),
                            (((1,), (1,)), ((), ())))
    y = y.reshape(-1, spec.hd)
    mu = mu.astype(jnp.float32)
    u = jnp.where(mu > 0, companding.expand(y, jnp.maximum(mu, 1.0)), y)
    u = u * amax.astype(jnp.float32)[:, None]
    if hd_out is not None and hd_out != spec.hd:
        u = jnp.pad(u, ((0, 0), (0, hd_out - spec.hd)))
    return u.astype(dtype)


# ---------------------------------------------------------------------------
# quantize / dequantize (shared by both backends)
# ---------------------------------------------------------------------------

def _check_mode(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"unknown cache mode {mode!r}; available: {MODES}")


def kv_quantize(x, mode: str) -> Tuple[jax.Array, jax.Array]:
    """x [..., KV, hd] -> (int8 codes [..., KV, hd], f16 amax [..., KV])."""
    if mode not in INT8_MODES:
        raise ValueError(f"kv_quantize handles the int8 modes {INT8_MODES}, "
                         f"got {mode!r} (paged_glvq uses glvq_quantize; "
                         f"paged stores the raw dtype)")
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-6)
    u = x / amax[..., None]
    if mode == "paged_q8c":
        u = companding.compand(u.astype(jnp.float32), KV_MU)
    codes = jnp.clip(jnp.round(u.astype(jnp.float32) * 127.0), -127, 127)
    return codes.astype(jnp.int8), amax.astype(jnp.float16)


def kv_dequantize(codes, amax, mode: str, dtype) -> jax.Array:
    """(int8 codes [..., KV, hd], f16 amax [..., KV]) -> values [..., KV, hd]."""
    if mode not in INT8_MODES:
        raise ValueError(f"kv_dequantize handles the int8 modes "
                         f"{INT8_MODES}, got {mode!r} (paged_glvq uses "
                         f"glvq_dequantize; paged stores the raw dtype)")
    u = codes.astype(jnp.float32) / 127.0
    if mode == "paged_q8c":
        u = companding.expand(u, KV_MU)
    return (u * amax.astype(jnp.float32)[..., None]).astype(dtype)


def chunk_roundtrip(k, v, *, mode: str, store_dtype, out_dtype,
                    glvq: Optional[GLVQSpec] = None,
                    book: Optional[Dict[str, jax.Array]] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Roundtrip a chunk's in-flight K/V through the cache codec.

    Sliding-window chunk attention reads the chunk's own keys before they
    land in the pools, so they must read back exactly what a later gather
    would return.  For the quantized kinds that is quantize -> dequantize;
    for ``paged`` the codec is a dtype cast — and when the pool stores the
    compute dtype already, an identity (the arrays are returned untouched,
    no casts).  ``paged_glvq`` additionally needs the layer's codebook
    (``book``: any mapping with the ``GLVQ_BOOK_LEAVES`` — the pool dict
    itself works; default: the identity book)."""
    _check_mode(mode)
    if mode == "paged":
        if jnp.dtype(store_dtype) == jnp.dtype(out_dtype):
            return k, v
        return (k.astype(store_dtype).astype(out_dtype),
                v.astype(store_dtype).astype(out_dtype))
    if mode == "paged_glvq":
        spec = glvq if glvq is not None else default_glvq_spec(k.shape[-1])
        bk = book if book is not None else glvq_default_book(k.shape[-2],
                                                             spec)
        return (glvq_dequantize(*glvq_quantize(k, bk["kgi"], bk["kmu"], spec),
                                bk["kg"], bk["kmu"], spec, out_dtype),
                glvq_dequantize(*glvq_quantize(v, bk["vgi"], bk["vmu"], spec),
                                bk["vg"], bk["vmu"], spec, out_dtype))
    return (kv_dequantize(*kv_quantize(k, mode), mode, out_dtype),
            kv_dequantize(*kv_quantize(v, mode), mode, out_dtype))


def pool_init(num_blocks: int, block_size: int, n_kv: int, hd: int, dtype,
              mode: str, *, glvq: Optional[GLVQSpec] = None,
              book: Optional[Dict[str, jax.Array]] = None,
              ) -> Dict[str, jax.Array]:
    """Per-layer pool leaves.  ``kp``/``vp`` are the K/V blocks; quantized
    modes add per-token-per-head scales ``ksc``/``vsc``; ``paged_glvq``
    stores word-packed lattice codes in ``kp``/``vp`` (uint32
    [nb, bs, KV, n_words]) plus the per-head codebook leaves
    (``GLVQ_BOOK_LEAVES``; ``book`` overrides the identity default with
    calibrated matrices)."""
    _check_mode(mode)
    if mode == "paged_glvq":
        spec = glvq if glvq is not None else default_glvq_spec(hd)
        if spec.hd != hd:
            raise ValueError(f"GLVQSpec.hd={spec.hd} != pool head dim {hd}")
        pools = dict(
            kp=jnp.zeros((num_blocks, block_size, n_kv, spec.n_words),
                         jnp.uint32),
            vp=jnp.zeros((num_blocks, block_size, n_kv, spec.n_words),
                         jnp.uint32),
            ksc=jnp.zeros((num_blocks, block_size, n_kv), jnp.float16),
            vsc=jnp.zeros((num_blocks, block_size, n_kv), jnp.float16),
        )
        bk = book if book is not None else glvq_default_book(n_kv, spec)
        pools.update({n: jnp.asarray(bk[n], jnp.float32)
                      for n in GLVQ_BOOK_LEAVES})
        return pools
    store = dtype if mode == "paged" else jnp.int8
    pools = dict(
        kp=jnp.zeros((num_blocks, block_size, n_kv, hd), store),
        vp=jnp.zeros((num_blocks, block_size, n_kv, hd), store),
    )
    if mode != "paged":
        pools["ksc"] = jnp.zeros((num_blocks, block_size, n_kv), jnp.float16)
        pools["vsc"] = jnp.zeros((num_blocks, block_size, n_kv), jnp.float16)
    return pools


def copy_pool_block(pool, src, dst, *, stacked: bool = False):
    """Duplicate one pool block's stored content: ``pool[dst] = pool[src]``
    (codes AND scales copy verbatim, so the clone dequantizes bit-identically
    to the original — the copy-on-write primitive behind prefix sharing).
    ``src``/``dst`` may be traced int scalars; ``stacked`` marks a leading
    scan-repeat axis ([R, NB, ...] — every repeat's layer copies the same
    block id, matching the shared block table)."""
    if stacked:
        return pool.at[:, dst].set(pool[:, src])
    return pool.at[dst].set(pool[src])


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_KV_BACKENDS: Dict[str, type] = {}


def register_kv_backend(name: str):
    """Decorator: register a namespace with ``append``/``gather`` staticmethods."""
    def deco(obj):
        _KV_BACKENDS[name] = obj
        return obj
    return deco


def kv_backends() -> Tuple[str, ...]:
    return tuple(sorted(_KV_BACKENDS))


def resolve_kv_backend(backend: Optional[str] = None) -> str:
    """explicit arg > REPRO_KV_BACKEND env > platform default."""
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND, "").strip() or None
    if backend is None:
        return "pallas" if _on_tpu() else "xla"
    if backend not in _KV_BACKENDS:
        raise ValueError(f"unknown kv backend {backend!r}; "
                         f"available: {kv_backends()}")
    return backend


# ---------------------------------------------------------------------------
# XLA fallback backend
# ---------------------------------------------------------------------------

@register_kv_backend("xla")
class _XlaKV:
    @staticmethod
    def append(cache, kq, vq, ks, vs, bids, offs):
        new = dict(cache)
        new["kp"] = cache["kp"].at[bids, offs].set(kq)
        new["vp"] = cache["vp"].at[bids, offs].set(vq)
        if ks is not None:
            new["ksc"] = cache["ksc"].at[bids, offs].set(ks)
            new["vsc"] = cache["vsc"].at[bids, offs].set(vs)
        return new

    @staticmethod
    def append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids):
        # bids/offs [B, T]; masked tokens arrive with bids == num_blocks,
        # which the drop-mode scatter discards.  prog_bids is the Pallas
        # backend's per-slot touched-block list — unused here.
        new = dict(cache)
        new["kp"] = cache["kp"].at[bids, offs].set(kq, mode="drop")
        new["vp"] = cache["vp"].at[bids, offs].set(vq, mode="drop")
        if ks is not None:
            new["ksc"] = cache["ksc"].at[bids, offs].set(ks, mode="drop")
            new["vsc"] = cache["vsc"].at[bids, offs].set(vs, mode="drop")
        return new

    @staticmethod
    def gather(cache, table, mode, out_dtype, glvq=None):
        b, nb = table.shape
        bs = cache["kp"].shape[1]
        flat = table.reshape(-1)

        def pull(pool):
            g = jnp.take(pool, flat, axis=0)          # [B*nb, bs, KV, hd]
            return g.reshape((b, nb * bs) + pool.shape[2:])

        kw, vw = pull(cache["kp"]), pull(cache["vp"])
        if mode == "paged":
            return kw.astype(out_dtype), vw.astype(out_dtype)
        ksc, vsc = pull(cache["ksc"]), pull(cache["vsc"])
        if mode == "paged_glvq":
            spec = glvq if glvq is not None else glvq_spec_from_pool(cache)
            return (glvq_dequantize(kw, ksc, cache["kg"], cache["kmu"],
                                    spec, out_dtype),
                    glvq_dequantize(vw, vsc, cache["vg"], cache["vmu"],
                                    spec, out_dtype))
        return (kv_dequantize(kw, ksc, mode, out_dtype),
                kv_dequantize(vw, vsc, mode, out_dtype))


# ---------------------------------------------------------------------------
# Pallas backend
# ---------------------------------------------------------------------------

def _pad_pool_leaf(name: str, arr):
    """Tile-align one pool leaf: token dim (1) to x8, head dim (kp/vp) to
    x128.  Offsets stay valid (< block_size) and gathered pad rows are
    sliced off before anything reads them."""
    if name in ("kp", "vp"):
        return pad_to(pad_to(arr, 1, 8), 3, 128)
    return pad_to(arr, 1, 8)


def _unpad_pool_leaf(name: str, arr, bs: int, hd: int):
    if name in ("kp", "vp"):
        return arr[:, :bs, :, :hd]
    return arr[:, :bs]


def _append_kernel(bids_ref, offs_ref, *refs, quant: bool):
    """Grid (B,): read-modify-write slot b's current block, one token row."""
    b = pl.program_id(0)
    o = offs_ref[b]
    n_arr = 4 if quant else 2
    news, ins, outs = refs[:n_arr], refs[n_arr:2 * n_arr], refs[2 * n_arr:]
    for new_ref, in_ref, out_ref in zip(news, ins, outs):
        out_ref[...] = in_ref[...]
        out_ref[0, o] = new_ref[0]


def _append_chunk_kernel(pbids_ref, bids_ref, offs_ref, *refs, quant: bool,
                         t: int, nb: int):
    """Grid (B, NB): read-modify-write pool block prog_bids[b, n], storing
    every slab token whose target block id matches it.  Masked tokens carry
    an out-of-pool sentinel bid and match no program."""
    b = pl.program_id(0)
    n = pl.program_id(1)
    mine = pbids_ref[b * nb + n]
    n_arr = 4 if quant else 2
    news, ins, outs = refs[:n_arr], refs[n_arr:2 * n_arr], refs[2 * n_arr:]
    for new_ref, in_ref, out_ref in zip(news, ins, outs):
        out_ref[...] = in_ref[...]
    for tok in range(t):
        @pl.when(bids_ref[b * t + tok] == mine)
        def _write(_tok=tok):
            o = offs_ref[b * t + _tok]
            for new_ref, out_ref in zip(news, outs):
                out_ref[0, o] = new_ref[0, _tok]


def _gather_kernel(tbl_ref, *refs, mode: str, out_dtype,
                   glvq: Optional[GLVQSpec] = None):
    """Grid (B, nb): dequantize pool block table[b, j] into out[b, j]."""
    if mode == "paged":
        kp, vp, gk, gv = refs
        gk[0, 0] = kp[0].astype(out_dtype)
        gv[0, 0] = vp[0].astype(out_dtype)
        return
    if mode == "paged_glvq":
        # pool blocks carry packed words; codebooks ride as const refs and
        # each KV head decodes with its own [d, d] generation matrix.
        kp, ksc, vp, vsc, kg, kmu, vg, vmu, gk, gv = refs
        hd_p = gk.shape[-1]
        for h in range(kg.shape[0]):
            gk[0, 0, :, h] = glvq_decode_head(kp[0][:, h], ksc[0][:, h],
                                              kg[h], kmu[h], glvq,
                                              out_dtype, hd_p)
            gv[0, 0, :, h] = glvq_decode_head(vp[0][:, h], vsc[0][:, h],
                                              vg[h], vmu[h], glvq,
                                              out_dtype, hd_p)
        return
    kp, ksc, vp, vsc, gk, gv = refs
    gk[0, 0] = kv_dequantize(kp[0], ksc[0], mode, out_dtype)
    gv[0, 0] = kv_dequantize(vp[0], vsc[0], mode, out_dtype)


@register_kv_backend("pallas")
class _PallasKV:
    @staticmethod
    def append(cache, kq, vq, ks, vs, bids, offs):
        quant = ks is not None
        news = (kq, vq, ks, vs) if quant else (kq, vq)
        pools = ("kp", "vp", "ksc", "vsc") if quant else ("kp", "vp")
        ins = tuple(cache[p] for p in pools)
        b = kq.shape[0]
        bs, _, hd = cache["kp"].shape[1:]
        padded = tile_pad_enabled() and padded_block_geom(bs, hd) != (bs, hd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
            news = tuple(pad_to(a, 2, 128) if a.ndim == 3 else a
                         for a in news)

        def tok_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, bids, offs, _nd=nd: (i,) + (0,) * _nd)

        def blk_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, bids, offs, _nd=nd:
                                (bids[i],) + (0,) * _nd)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b,),
            in_specs=[tok_spec(a) for a in news] + [blk_spec(a) for a in ins],
            out_specs=tuple(blk_spec(a) for a in ins),
        )
        # alias each pool input onto its output: in-place block update
        aliases = {2 + len(news) + i: i for i in range(len(ins))}
        outs = pl.pallas_call(
            functools.partial(_append_kernel, quant=quant),
            grid_spec=grid_spec,
            out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ins),
            input_output_aliases=aliases,
            interpret=not _on_tpu(),
        )(bids, offs, *news, *ins)
        if padded:
            outs = tuple(_unpad_pool_leaf(n, a, bs, hd)
                         for n, a in zip(pools, outs))
        new = dict(cache)
        new.update(dict(zip(pools, outs)))
        return new

    @staticmethod
    def append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids):
        quant = ks is not None
        news = (kq, vq, ks, vs) if quant else (kq, vq)
        pools = ("kp", "vp", "ksc", "vsc") if quant else ("kp", "vp")
        ins = tuple(cache[p] for p in pools)
        b, t = bids.shape
        nb = prog_bids.shape[1]
        bs, _, hd = cache["kp"].shape[1:]
        padded = tile_pad_enabled() and padded_block_geom(bs, hd) != (bs, hd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
            news = tuple(pad_to(a, 3, 128) if a.ndim == 4 else a
                         for a in news)

        def tok_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, j, pb, bd, of, _nd=nd:
                                (i,) + (0,) * _nd)

        def blk_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec((1,) + arr.shape[1:],
                                lambda i, j, pb, bd, of, _nd=nd:
                                (pb[i * nb + j],) + (0,) * _nd)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, nb),
            in_specs=[tok_spec(a) for a in news] + [blk_spec(a) for a in ins],
            out_specs=tuple(blk_spec(a) for a in ins),
        )
        aliases = {3 + len(news) + i: i for i in range(len(ins))}
        outs = pl.pallas_call(
            functools.partial(_append_chunk_kernel, quant=quant, t=t, nb=nb),
            grid_spec=grid_spec,
            out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ins),
            input_output_aliases=aliases,
            interpret=not _on_tpu(),
        )(prog_bids.reshape(-1), bids.reshape(-1), offs.reshape(-1), *news,
          *ins)
        if padded:
            outs = tuple(_unpad_pool_leaf(n, a, bs, hd)
                         for n, a in zip(pools, outs))
        new = dict(cache)
        new.update(dict(zip(pools, outs)))
        return new

    @staticmethod
    def gather(cache, table, mode, out_dtype, glvq=None):
        b, nb = table.shape
        bs, kv, pd = cache["kp"].shape[1:]       # pd: stored last dim
        is_glvq = mode == "paged_glvq"
        spec = None
        if is_glvq:
            spec = glvq if glvq is not None else glvq_spec_from_pool(cache)
            hd = spec.hd                          # decoded head dim != pd
        else:
            hd = pd
        quant = mode != "paged"
        pools = (("kp", "ksc", "vp", "vsc") if quant else ("kp", "vp"))
        ins = tuple(cache[p] for p in pools)
        padded = tile_pad_enabled() and padded_block_geom(bs, pd) != (bs, pd)
        if padded:
            ins = tuple(_pad_pool_leaf(n, a) for n, a in zip(pools, ins))
        bs_p = ins[0].shape[1]
        hd_p = (padded_block_geom(bs, hd)[1] if tile_pad_enabled() else hd)
        consts = ((cache["kg"], cache["kmu"], cache["vg"], cache["vmu"])
                  if is_glvq else ())

        def pool_spec(arr):
            nd = arr.ndim - 1
            return pl.BlockSpec(
                (1,) + arr.shape[1:],
                lambda i, j, tbl, _nd=nd:
                (tbl[i * nb + j],) + (0,) * _nd)

        def const_spec(arr):
            nd = arr.ndim
            return pl.BlockSpec(arr.shape,
                                lambda i, j, tbl, _nd=nd: (0,) * _nd)

        out_spec = pl.BlockSpec((1, 1, bs_p, kv, hd_p),
                                lambda i, j, tbl: (i, j, 0, 0, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nb),
            in_specs=([pool_spec(a) for a in ins]
                      + [const_spec(a) for a in consts]),
            out_specs=(out_spec, out_spec),
        )
        out_sds = jax.ShapeDtypeStruct((b, nb, bs_p, kv, hd_p), out_dtype)
        gk, gv = pl.pallas_call(
            functools.partial(_gather_kernel, mode=mode, out_dtype=out_dtype,
                              glvq=spec),
            grid_spec=grid_spec,
            out_shape=(out_sds, out_sds),
            interpret=not _on_tpu(),
        )(table.reshape(-1), *ins, *consts)
        if bs_p != bs or hd_p != hd:
            gk, gv = gk[:, :, :bs, :, :hd], gv[:, :, :bs, :, :hd]
        return gk.reshape(b, nb * bs, kv, hd), gv.reshape(b, nb * bs, kv, hd)


# ---------------------------------------------------------------------------
# Public entry points (mode-aware, backend-dispatched)
# ---------------------------------------------------------------------------

def append(cache: Dict[str, jax.Array], k_new, v_new, bids, offs, *,
           mode: str, backend: Optional[str] = None,
           glvq: Optional[GLVQSpec] = None) -> Dict[str, jax.Array]:
    """Write one token per slot.  k_new/v_new [B, KV, hd]; bids/offs [B] int32
    (the slot's current block id / in-block offset).  Returns the new cache."""
    _check_mode(mode)
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_append[{mode}]"):
        if mode == "paged":
            store = cache["kp"].dtype
            return be.append(cache, k_new.astype(store), v_new.astype(store),
                             None, None, bids, offs)
        if mode == "paged_glvq":
            spec = glvq if glvq is not None else glvq_spec_from_pool(cache)
            kq, ks = glvq_quantize(k_new, cache["kgi"], cache["kmu"], spec)
            vq, vs = glvq_quantize(v_new, cache["vgi"], cache["vmu"], spec)
            return be.append(cache, kq, vq, ks, vs, bids, offs)
        kq, ks = kv_quantize(k_new, mode)
        vq, vs = kv_quantize(v_new, mode)
        return be.append(cache, kq, vq, ks, vs, bids, offs)


def append_chunk(cache: Dict[str, jax.Array], k_new, v_new, bids, offs,
                 valid, prog_bids, *, mode: str,
                 backend: Optional[str] = None,
                 glvq: Optional[GLVQSpec] = None) -> Dict[str, jax.Array]:
    """Write up to T tokens per slot in one call (chunked prefill).

    k_new/v_new [B, T, KV, hd]; bids/offs [B, T] int32 target block id /
    in-block offset per slab token; valid [B, T] bool masks pad positions
    (their writes are dropped).  ``prog_bids`` [B, NB] int32 lists the pool
    blocks each slot's chunk touches (entries must be distinct per slot or
    the scratch block 0) — the Pallas backend runs one grid program per
    (slot, touched block); the XLA backend scatters directly and ignores it.
    Returns the new cache."""
    _check_mode(mode)
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    num_blocks = cache["kp"].shape[0]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_append_chunk[{mode}]"):
        bids = jnp.where(valid, bids, num_blocks).astype(jnp.int32)
        offs = offs.astype(jnp.int32)
        if mode == "paged":
            store = cache["kp"].dtype
            return be.append_chunk(cache, k_new.astype(store),
                                   v_new.astype(store), None, None, bids,
                                   offs, prog_bids)
        if mode == "paged_glvq":
            spec = glvq if glvq is not None else glvq_spec_from_pool(cache)
            kq, ks = glvq_quantize(k_new, cache["kgi"], cache["kmu"], spec)
            vq, vs = glvq_quantize(v_new, cache["vgi"], cache["vmu"], spec)
            return be.append_chunk(cache, kq, vq, ks, vs, bids, offs,
                                   prog_bids)
        kq, ks = kv_quantize(k_new, mode)
        vq, vs = kv_quantize(v_new, mode)
        return be.append_chunk(cache, kq, vq, ks, vs, bids, offs, prog_bids)


def gather(cache: Dict[str, jax.Array], table, *, mode: str,
           backend: Optional[str] = None, out_dtype=jnp.float32,
           glvq: Optional[GLVQSpec] = None) -> Tuple[jax.Array, jax.Array]:
    """Read blocks ``table`` [B, nb] back as dense dequantized history:
    (k, v) each [B, nb * block_size, KV, hd] in logical token order."""
    _check_mode(mode)
    be = _KV_BACKENDS[resolve_kv_backend(backend)]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"kv_gather[{mode}]"):
        return be.gather(cache, table, mode, out_dtype, glvq=glvq)
