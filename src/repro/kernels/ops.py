"""Quantized-execution engine: backend registry + jit'd Pallas wrappers.

This module is the single dispatch point for "a matmul against quantized
weights".  Every consumer (models, serving, launch, benchmarks) goes through
``quant_matmul`` / ``quant_matmul_segments`` / ``quant_decode`` — or, one
level up, through ``repro.core.qtensor.QuantTensor`` which bundles payload +
meta and calls down into this registry.

Backends
--------
  * ``pallas_fused`` — Pallas TPU fused decode+GEMM (kernels.glvq_matmul);
    the weight never materializes in HBM.  Interpret-mode on CPU.
  * ``xla_decode``   — pure-jnp unpack + blocked G·Z + inverse companding,
    then a dense GEMM; XLA fuses the unpack arithmetic but materializes W.
  * ``reference``    — the jnp oracle in kernels.ref (ground truth, slow).

Selection: explicit ``backend=`` argument > ``REPRO_QUANT_BACKEND`` env var >
platform default (``pallas_fused`` on TPU, ``xla_decode`` elsewhere).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import per_word, unit_codes
from repro.kernels.babai_quant import babai_quantize_pallas
from repro.kernels.glvq_matmul import glvq_matmul_pallas

__all__ = ["glvq_matmul", "babai_quantize", "pick_n_block",
           "register_matmul_backend", "matmul_backends", "resolve_backend",
           "quant_matmul", "quant_matmul_segments", "quant_matmul_cols",
           "quant_decode", "tp_shardable", "quant_matmul_tp",
           "quant_matmul_segments_tp"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_n_block(n_pad: int, bits: int, d: int, target: int = 512) -> int:
    """Largest Nb <= target with Nb % (per_word*d) == 0 and Nb | n_pad."""
    unit = unit_codes(bits, d)
    best = unit
    nb = unit
    while nb <= min(target, n_pad):
        if n_pad % nb == 0:
            best = nb
        nb += unit
    return best


@functools.partial(jax.jit, static_argnames=("bits", "d", "group_size",
                                             "n", "interpret"))
def glvq_matmul(x, packed, g, mu, scale, *, bits: int, d: int, n: int,
                group_size: int = 128, interpret: bool | None = None):
    """y = x @ dequant(codes);  x [M, K], packed [K, n_words] -> y [M, n]."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    pw = per_word(bits)
    # keep the M tile MXU-sized: pad M up to the next multiple of the block
    # instead of degrading to m_block=1 (a 4-slot decode batch would
    # otherwise run 4 grid rows of 1xK GEMMs)
    m_block = 128 if m % 128 == 0 else 8
    mb_pad = -m % m_block
    if mb_pad:
        x = jnp.pad(x, ((0, mb_pad), (0, 0)))
    # pad n_words so n_pad is a whole number of (per_word, d)-aligned units
    # (bits=3 payloads with small N otherwise have no valid block size)
    unit = unit_codes(bits, d)
    w_words = packed.shape[1]
    while (w_words * pw) % unit:
        w_words += 1
    if w_words != packed.shape[1]:
        packed = jnp.pad(packed, ((0, 0), (0, w_words - packed.shape[1])))
    n_pad = w_words * pw
    n_block = pick_n_block(n_pad, bits, d)
    out = glvq_matmul_pallas(x, packed, g, mu, scale, bits=bits, d=d,
                             group_size=group_size, m_block=m_block,
                             n_block=n_block, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bits", "d", "group_size",
                                             "interpret"))
def babai_quantize(w, g_inv, mu, scale, *, bits: int, d: int,
                   group_size: int = 128, interpret: bool | None = None):
    """codes[K, N] = clip(round(G^{-1} F_mu(W / scale)))."""
    if interpret is None:
        interpret = not _on_tpu()
    k, n = w.shape
    n_block = pick_n_block(n, 8, d, target=512)  # only needs d | Nb | N
    if n % n_block:
        n_block = d
    return babai_quantize_pallas(w, g_inv, mu, scale, bits=bits, d=d,
                                 group_size=group_size, n_block=n_block,
                                 interpret=interpret)


# ---------------------------------------------------------------------------
# Backend registry (the quantized-matmul engine)
# ---------------------------------------------------------------------------

# name -> fn(x2 [M, K], payload dict, QuantLinearMeta) -> y [M, n]
_MATMUL_BACKENDS: Dict[str, Callable] = {}

_ENV_BACKEND = "REPRO_QUANT_BACKEND"


def register_matmul_backend(name: str):
    """Decorator: register ``fn(x [M, K], payload, meta) -> y [M, n]``."""
    def deco(fn):
        _MATMUL_BACKENDS[name] = fn
        return fn
    return deco


def matmul_backends() -> Tuple[str, ...]:
    return tuple(sorted(_MATMUL_BACKENDS))


def resolve_backend(backend: Optional[str] = None) -> str:
    """explicit arg > REPRO_QUANT_BACKEND env > platform default."""
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND, "").strip() or None
    if backend is None:
        return "pallas_fused" if _on_tpu() else "xla_decode"
    if backend not in _MATMUL_BACKENDS:
        raise ValueError(f"unknown quant backend {backend!r}; "
                         f"available: {matmul_backends()}")
    return backend


@register_matmul_backend("pallas_fused")
def _backend_pallas_fused(x, payload, meta):
    return glvq_matmul(x, payload["packed"], payload["g"], payload["mu"],
                       payload["scale"], bits=meta.bits, d=meta.d, n=meta.n,
                       group_size=meta.group_size)


@register_matmul_backend("xla_decode")
def _backend_xla_decode(x, payload, meta):
    from repro.core import quantized
    w = quantized.decode_xla(payload, meta).astype(x.dtype)
    return x @ w


@register_matmul_backend("reference")
def _backend_reference(x, payload, meta):
    from repro.kernels import ref
    return ref.glvq_matmul_ref(x, payload["packed"], payload["g"],
                               payload["mu"], payload["scale"],
                               bits=meta.bits, d=meta.d, n=meta.n,
                               group_size=meta.group_size)


def quant_matmul(x, payload, meta, *, backend: Optional[str] = None,
                 out_dtype=None):
    """y = x @ dequant(payload).  x [..., K] (leading dims flattened to M),
    unstacked payload.  The one entry point every call site dispatches through."""
    name = resolve_backend(backend)
    out_dtype = out_dtype or x.dtype
    batch = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = _MATMUL_BACKENDS[name](x2, payload, meta)
    return y.reshape(batch + (meta.n,)).astype(out_dtype)


def quant_matmul_segments(x, segments: Sequence, group_size: int, n: int, *,
                          backend: Optional[str] = None, out_dtype=None):
    """Mixed-bit (SDBA) fused matmul: loop uniform-bit segments through the
    backend and sum partial products.

    ``segments`` is a sequence of ``(meta, payload, group_idx)`` where
    ``group_idx`` gives each segment row-group's position in the original
    [K, N] weight.  Because SDBA splits along K (input groups), the fix-up is
    an input-side gather: segment s contracts x's columns at its groups, and
    every segment emits a full-N partial product — no output permutation
    remains after the sum.
    """
    name = resolve_backend(backend)
    out_dtype = out_dtype or x.dtype
    batch = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = None
    for meta, payload, gidx in segments:
        idx = np.asarray(gidx, np.int64)
        cols = (idx[:, None] * group_size
                + np.arange(group_size)[None, :]).reshape(-1)
        xs = jnp.take(x2, jnp.asarray(cols), axis=1)
        ys = _MATMUL_BACKENDS[name](xs, payload, meta)
        y = ys if y is None else y + ys
    return y.reshape(batch + (n,)).astype(out_dtype)


def quant_matmul_cols(x, parts: Sequence, *, backend: Optional[str] = None,
                      out_dtype=None):
    """Column-fused multi-weight matmul: y = x @ [W_0 | W_1 | ...].

    ``parts`` is a sequence of ``(payload, meta)`` sharing the same K — the
    q/k/v (or gate/up) projections of one block, which all contract the same
    activations.  The activation slab is reshaped and streamed ONCE for the
    whole group; on ``xla_decode`` the decoded weights concatenate into a
    single [K, sum(N_i)] GEMM so the M-blocking amortizes across every
    projection instead of re-running per weight.  Returns y [..., sum(N_i)].
    """
    name = resolve_backend(backend)
    out_dtype = out_dtype or x.dtype
    batch = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if name == "xla_decode":
        from repro.core import quantized
        w = jnp.concatenate([quantized.decode_xla(p, m).astype(x2.dtype)
                             for p, m in parts], axis=1)
        y = x2 @ w
    else:
        y = jnp.concatenate([_MATMUL_BACKENDS[name](x2, p, m)
                             for p, m in parts], axis=1)
    return y.reshape(batch + (y.shape[-1],)).astype(out_dtype)


# ---------------------------------------------------------------------------
# Tensor-parallel execution (shard_map over the "model" mesh axis)
# ---------------------------------------------------------------------------
#
# The packed codes are the natural unit to shard: decoding is a per-column
# (column-parallel) or per-group (row-parallel) operation, so each device
# runs the SAME fused kernel on its local payload slice and the weight stays
# compressed *and* distributed.
#
#   column-parallel  packed [K, n_words] shards n_words in word-unit-aligned
#                    chunks (whole uint32 words AND whole lattice vectors);
#                    g/mu/scale are per-K-group side info — replicated.  The
#                    out_spec shards N, so shard_map's output IS the
#                    concatenation: no collective at all.
#   row-parallel     packed shards K in whole code groups; g/mu/scale shard
#                    their group dim with it; x shards K; each device emits a
#                    full-N partial product and a psum finishes the GEMM.

import dataclasses as _dataclasses

from jax.sharding import PartitionSpec as _P


def _tp_size(mesh, axis: str) -> int:
    return dict(mesh.shape).get(axis, 1)


def tp_shardable(meta, tp: int, parallel: str) -> bool:
    """Can this payload execute tp-way sharded without GSPMD padding?

    column: N must split into tp chunks of whole words and whole d-vectors
    (and carry no pad codes in the last word); row: K must split into tp
    chunks of whole code groups."""
    if tp <= 1:
        return False
    if parallel == "column":
        return meta.n % (tp * unit_codes(meta.bits, meta.d)) == 0
    if parallel == "row":
        return meta.n_groups % tp == 0
    raise ValueError(f"parallel must be 'column' or 'row', got {parallel!r}")


def _payload_specs(payload, parallel: str, axis: str):
    if parallel == "column":
        by_name = dict(packed=_P(None, axis), g=_P(None, None, None),
                       mu=_P(None), scale=_P(None))
    else:
        by_name = dict(packed=_P(axis, None), g=_P(axis, None, None),
                       mu=_P(axis), scale=_P(axis))
    return {k: by_name[k] for k in payload}


def _m_axes(mesh, m: int, axis: str):
    """Data axes to shard the flattened M (batch) dim over, so TP composes
    with data parallelism instead of all-gathering activations: every axis of
    the mesh other than the TP axis, when M divides evenly.  Returns None
    (replicate M) otherwise."""
    axes = tuple(a for a in mesh.axis_names if a != axis)
    dp = math.prod(dict(mesh.shape)[a] for a in axes)
    if not axes or dp <= 1 or m % dp:
        return None
    return axes if len(axes) > 1 else axes[0]


def _shard_map():
    from repro.optim.compression import shard_map_fn
    return shard_map_fn()


def quant_matmul_tp(x, payload, meta, *, mesh, parallel: str = "column",
                    axis: str = "model", backend: Optional[str] = None,
                    out_dtype=None):
    """Tensor-parallel y = x @ dequant(payload) over ``mesh[axis]``.

    Falls back to the replicated ``quant_matmul`` when the mesh axis is
    trivial, the payload is not cleanly shardable, or this jax has no
    shard_map — callers never need to pre-check."""
    tp = _tp_size(mesh, axis)
    smap = _shard_map()
    if smap is None or not tp_shardable(meta, tp, parallel):
        return quant_matmul(x, payload, meta, backend=backend,
                            out_dtype=out_dtype)
    name = resolve_backend(backend)
    out_dtype = out_dtype or x.dtype
    batch = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    pspecs = _payload_specs(payload, parallel, axis)
    ma = _m_axes(mesh, x2.shape[0], axis)     # keep data parallelism intact
    if parallel == "column":
        lmeta = _dataclasses.replace(meta, n=meta.n // tp)
        xspec, out_spec = _P(ma, None), _P(ma, axis)

        def fn(x_l, pl_l):
            return _MATMUL_BACKENDS[name](x_l, pl_l, lmeta)
    else:
        lmeta = _dataclasses.replace(meta, k=meta.k // tp)
        xspec, out_spec = _P(ma, axis), _P(ma, None)

        def fn(x_l, pl_l):
            return jax.lax.psum(_MATMUL_BACKENDS[name](x_l, pl_l, lmeta),
                                axis)

    y = smap(fn, mesh=mesh, in_specs=(xspec, pspecs),
             out_specs=out_spec)(x2, payload)
    return y.reshape(batch + (meta.n,)).astype(out_dtype)


def quant_matmul_segments_tp(x, segments: Sequence, group_size: int, n: int,
                             *, mesh, parallel: str = "column",
                             axis: str = "model",
                             backend: Optional[str] = None, out_dtype=None):
    """Tensor-parallel mixed-bit (SDBA) fused matmul.

    column: every segment's packed codes shard N; each device sums its
    segments' partial products over its N-shard (no collective).  row: every
    segment's K shards into whole code groups; each device gathers the x
    columns its group sub-range contracts (offset by its position on the
    mesh axis) and one psum finishes the sum over both segments and devices.
    Falls back to the replicated path unless EVERY segment is shardable."""
    tp = _tp_size(mesh, axis)
    smap = _shard_map()
    metas = [m for m, _, _ in segments]
    if smap is None or tp <= 1 or \
            not all(tp_shardable(m, tp, parallel) for m in metas):
        return quant_matmul_segments(x, segments, group_size, n,
                                     backend=backend, out_dtype=out_dtype)
    name = resolve_backend(backend)
    out_dtype = out_dtype or x.dtype
    batch = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    payloads = tuple(p for _, p, _ in segments)
    cols = []
    for _, _, gidx in segments:
        idx = np.asarray(gidx, np.int64)
        cols.append(jnp.asarray(
            (idx[:, None] * group_size
             + np.arange(group_size)[None, :]).reshape(-1)))
    pspecs = tuple(_payload_specs(p, parallel, axis) for p in payloads)
    ma = _m_axes(mesh, x2.shape[0], axis)     # keep data parallelism intact
    if parallel == "column":
        lmetas = [_dataclasses.replace(m, n=m.n // tp) for m in metas]
        out_spec = _P(ma, axis)

        def fn(x_l, pls):
            y = None
            for lm, pl, c in zip(lmetas, pls, cols):
                ys = _MATMUL_BACKENDS[name](jnp.take(x_l, c, axis=1), pl, lm)
                y = ys if y is None else y + ys
            return y
    else:
        lmetas = [_dataclasses.replace(m, k=m.k // tp) for m in metas]
        out_spec = _P(ma, None)

        def fn(x_l, pls):
            t = jax.lax.axis_index(axis)
            y = None
            for lm, pl, c in zip(lmetas, pls, cols):
                idx = jax.lax.dynamic_slice(c, (t * lm.k,), (lm.k,))
                ys = _MATMUL_BACKENDS[name](jnp.take(x_l, idx, axis=1),
                                            pl, lm)
                y = ys if y is None else y + ys
            return jax.lax.psum(y, axis)

    y = smap(fn, mesh=mesh, in_specs=(_P(ma, None), pspecs),
             out_specs=out_spec)(x2, payloads)
    return y.reshape(batch + (n,)).astype(out_dtype)


def quant_decode(payload, meta, *, dtype=jnp.float32):
    """Materialize dense W [lead..., K, N] from a (possibly stacked) payload.

    Explicit opt-in (CPU dry-runs, debugging, fake-quant eval) — the serving
    hot path never calls this; it dispatches ``quant_matmul`` instead."""
    from repro.core import quantized
    packed = payload["packed"]
    lead = packed.shape[:-2]
    if not lead:
        return quantized.decode_xla(payload, meta).astype(dtype)
    flat = {k: v.reshape((-1,) + v.shape[len(lead):])
            for k, v in payload.items()}
    w = jax.vmap(lambda p: quantized.decode_xla(p, meta))(flat)
    return w.reshape(lead + (meta.k, meta.n)).astype(dtype)
