"""jit'd public wrappers for the Pallas kernels.

Handles block-size selection, padding to block multiples, and backend
selection: on CPU (this container) the kernels run in interpret mode to
validate the kernel bodies; on TPU set interpret=False for compiled Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.packing import per_word
from repro.kernels.babai_quant import babai_quantize_pallas
from repro.kernels.glvq_matmul import glvq_matmul_pallas

__all__ = ["glvq_matmul", "babai_quantize", "pick_n_block"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_n_block(n_pad: int, bits: int, d: int, target: int = 512) -> int:
    """Largest Nb <= target with Nb % (per_word*d) == 0 and Nb | n_pad."""
    unit = per_word(bits) * d // math.gcd(per_word(bits), d)
    best = unit
    nb = unit
    while nb <= min(target, n_pad):
        if n_pad % nb == 0:
            best = nb
        nb += unit
    return best


@functools.partial(jax.jit, static_argnames=("bits", "d", "group_size",
                                             "n", "interpret"))
def glvq_matmul(x, packed, g, mu, scale, *, bits: int, d: int, n: int,
                group_size: int = 128, interpret: bool | None = None):
    """y = x @ dequant(codes);  x [M, K], packed [K, n_words] -> y [M, n]."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    pw = per_word(bits)
    n_pad = packed.shape[1] * pw
    m_block = 128 if m % 128 == 0 else (8 if m % 8 == 0 else 1)
    mb_pad = -m % m_block
    if mb_pad:
        x = jnp.pad(x, ((0, mb_pad), (0, 0)))
    n_block = pick_n_block(n_pad, bits, d)
    out = glvq_matmul_pallas(x, packed, g, mu, scale, bits=bits, d=d,
                             group_size=group_size, m_block=m_block,
                             n_block=n_block, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bits", "d", "group_size",
                                             "interpret"))
def babai_quantize(w, g_inv, mu, scale, *, bits: int, d: int,
                   group_size: int = 128, interpret: bool | None = None):
    """codes[K, N] = clip(round(G^{-1} F_mu(W / scale)))."""
    if interpret is None:
        interpret = not _on_tpu()
    k, n = w.shape
    n_block = pick_n_block(n, 8, d, target=512)  # only needs d | Nb | N
    if n % n_block:
        n_block = d
    return babai_quantize_pallas(w, g_inv, mu, scale, bits=bits, d=d,
                                 group_size=group_size, n_block=n_block,
                                 interpret=interpret)
