"""Fused paged-attention kernels: block gather + dequant + flash SDPA.

The serving attention path reads a slot's paged KV history (``kernels.
kv_cache`` block pools, int8 / mu-law codes for the quantized kinds) and
runs masked SDPA over it.  Done as separate ops that moves the DEQUANTIZED
cache through HBM twice per step: gather materializes a dense
``[B, S, KV, hd]`` slab, attention reads it back.  The fused Pallas kernel
here walks the per-slot block table with scalar prefetch and streams each
block through VMEM — dequant + online-softmax (running max / denominator,
flash-attention style) happen in registers, so neither the dense slab nor
the dequantized cache ever exists in HBM; per decode token the cache moves
once, as codes.

One kernel family covers both program widths of the unified serving step:

  * decode (T=1) and chunk (T>1) — the query grid packs ``n_rep * T`` rows
    per KV head (GQA head-group mapping), each row masked by its own
    absolute position;
  * global layers (causal prefix masking over the appended history) and
    sliding-window layers (ring semantics: the pre-append ring is attended
    together with the chunk's in-flight keys, exactly mirroring
    ``models.layers`` — a grid step past the last table block handles the
    in-flight chunk);
  * all paged cache kinds: ``paged`` (cast only), ``paged_q8`` (int8 +
    per-token-per-head scale), ``paged_q8c`` (mu-law companded int8) — the
    dequant math is ``kv_cache.kv_dequantize``, shared with the unfused
    path — and ``paged_glvq`` (word-packed lattice codes): the per-head
    generation matrices ride into the kernel as per-grid-step codebook
    blocks and each pool block decodes in VMEM via
    ``kv_cache.glvq_decode_head`` (unpack -> [n_vec, d] @ G^T -> mu-law
    expand -> amax rescale), so HBM only ever moves ~4-bit codes.

Backends mirror the ``kernels.kv_cache`` registry: ``pallas`` (the fused
kernel; interpret mode off-TPU) and ``xla`` (gather-then-SDPA, today's
path, kept as the parity oracle).  Selection: explicit arg >
``REPRO_ATTN_BACKEND`` env > platform default (pallas on TPU, xla
elsewhere).  With a tensor-parallel ``mesh`` the call shard_maps over the
"model" axis: heads (and the KV-head dim of the pools) shard, the block
table / positions stay replicated, and no collective is needed — each
shard owns whole (kv-head, query-group) pairs.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import kv_cache

__all__ = ["NEG_INF", "register_attn_backend", "attn_backends",
           "resolve_attn_backend", "ring_positions", "window_chunk_masks",
           "masked_sdpa", "paged_attention"]

NEG_INF = -1e30

_ENV_BACKEND = "REPRO_ATTN_BACKEND"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_ATTN_BACKENDS: Dict[str, type] = {}


def register_attn_backend(name: str):
    """Decorator: register a namespace with a ``paged_attention`` staticmethod."""
    def deco(obj):
        _ATTN_BACKENDS[name] = obj
        return obj
    return deco


def attn_backends() -> Tuple[str, ...]:
    return tuple(sorted(_ATTN_BACKENDS))


def resolve_attn_backend(backend: Optional[str] = None) -> str:
    """explicit arg > REPRO_ATTN_BACKEND env > platform default."""
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND, "").strip() or None
    if backend is None:
        return "pallas" if _on_tpu() else "xla"
    if backend not in _ATTN_BACKENDS:
        raise ValueError(f"unknown attention backend {backend!r}; "
                         f"available: {attn_backends()}")
    return backend


# ---------------------------------------------------------------------------
# Mask math + masked SDPA (shared by the oracle, the dense path, and tests)
# ---------------------------------------------------------------------------

def ring_positions(last, size: int, modulus: int):
    """Absolute position stored at each ring index after the newest write
    landed at position ``last`` (ring slot = pos % modulus).  Entries that
    were never written (stored position would be negative, or index >=
    modulus) come back negative."""
    idx = jnp.arange(size)[None, :]
    stored = last[:, None] - (last[:, None] - idx) % modulus
    return jnp.where(idx < modulus, stored, -1)


def window_chunk_masks(pos, apos, t: int, size: int, window: int):
    """Key-validity masks for a chunked sliding-window step.

    The ring is read BEFORE the chunk's writes land (a chunk overwrites ring
    slots that its own earlier queries still need — the token-by-token
    oracle saw those keys), so attention runs over [pre-append ring ++
    in-flight chunk keys].  Returns (hist [B,T,size], intra [1,T,T])."""
    aq = apos[:, :, None]                                     # [B, T, 1]
    stored = ring_positions(pos - 1, size, window)[:, None, :]
    hist = (stored >= 0) & (stored <= aq) & (stored > aq - window)
    intra = (jnp.arange(t)[None, None, :] <= jnp.arange(t)[None, :, None])
    return hist, intra


def masked_sdpa(q, ck, cv, valid, *, n_rep: int, scale: float):
    """Masked attention over gathered history.
    q [B,Sq,H,hd]; ck/cv [B,Sk,KV,hd]; valid [B,Sk] (shared by all queries)
    or [B,Sq,Sk] (per-query) bool -> out [B,Sq,H*hd]."""
    b, sq, _, hd = q.shape
    kv = ck.shape[2]
    scores = jnp.einsum("bsgrd,btgd->bgrst",
                        q.reshape(b, sq, kv, n_rep, hd),
                        ck).astype(jnp.float32) * scale
    vm = valid[:, None, None, :, :] if valid.ndim == 3 \
        else valid[:, None, None, None, :]
    scores = jnp.where(vm, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    return jnp.einsum("bgrst,btgd->bsgrd", probs, cv).reshape(b, sq, -1)


# ---------------------------------------------------------------------------
# XLA oracle backend: gather-then-SDPA (the pre-fusion serving path)
# ---------------------------------------------------------------------------

@register_attn_backend("xla")
class _XlaAttn:
    @staticmethod
    def paged_attention(q, cache, table, pos, lens, *, mode, window,
                        k_chunk, v_chunk, kv_backend, out_dtype, glvq=None):
        b, t, h, hd = q.shape
        kv = cache["kp"].shape[2]
        bs = cache["kp"].shape[1]
        nb = table.shape[1]
        n_rep = h // kv
        ck, cv = kv_cache.gather(cache, table, mode=mode, backend=kv_backend,
                                 out_dtype=out_dtype, glvq=glvq)
        apos = pos[:, None] + jnp.arange(t)[None]             # [B, T]
        if window:
            hist, intra = window_chunk_masks(pos, apos, t, nb * bs, window)
            kk = jnp.concatenate([ck, k_chunk], axis=1)
            vv = jnp.concatenate([cv, v_chunk], axis=1)
            valid = jnp.concatenate(
                [hist, jnp.broadcast_to(intra, (b, t, t))], axis=-1)
        else:
            kk, vv = ck, cv
            valid = jnp.arange(nb * bs)[None, None, :] <= apos[:, :, None]
        out = masked_sdpa(q, kk, vv, valid, n_rep=n_rep, scale=hd ** -0.5)
        return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Fused Pallas backend
# ---------------------------------------------------------------------------

def _fused_attn_kernel(tbl_ref, pos_ref, *refs, mode: str, window: int,
                       t: int, bs: int, nb: int, scale: float,
                       has_chunk: bool,
                       glvq: Optional[kv_cache.GLVQSpec] = None):
    """Grid (B, KV, nb [+1]): one program per (slot, kv head, table block).

    The query block holds all ``n_rep * T`` rows of one (slot, kv head) —
    row ``rep * T + tq`` is query token ``tq`` of GQA group member ``rep``.
    Online softmax state (running max / denominator / accumulator) lives in
    VMEM scratch across the sequential block walk; with ``has_chunk`` the
    final grid step attends the in-flight chunk keys (sliding-window layers
    read the pre-append ring, so the chunk's own keys arrive separately).
    ``paged_glvq`` adds this head's codebook (G / mu per K and V) as four
    extra refs and decodes packed words in VMEM."""
    quant = mode != "paged"
    is_glvq = mode == "paged_glvq"
    n_in = (4 if quant else 2) + (4 if is_glvq else 0) \
        + (2 if has_chunk else 0)
    q_ref = refs[0]
    ins = refs[1:1 + n_in]
    o_ref, m_ref, l_ref, acc_ref = refs[1 + n_in:]
    if quant:
        kp_ref, vp_ref, ksc_ref, vsc_ref = ins[:4]
        rest = ins[4:]
    else:
        kp_ref, vp_ref = ins[:2]
        rest = ins[2:]
    if is_glvq:
        kg_ref, kmu_ref, vg_ref, vmu_ref = rest[:4]
        rest = rest[4:]

    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    r = q_ref.shape[2]                           # padded n_rep * T rows
    tq = jax.lax.broadcasted_iota(jnp.int32, (r, 1), 0) % t
    aq = pos_ref[b] + tq                         # [R, 1] absolute query pos

    def _accumulate(k, v, valid):
        """One online-softmax update.  k/v [S, hd] f32; valid [R, S]."""
        qf = q_ref[0, 0].astype(jnp.float32)                     # [R, hd]
        s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # masked keys must contribute EXACTLY zero: while every key so far
        # is masked m_new is still NEG_INF and exp(s - m_new) = exp(0) = 1
        # would poison the denominator
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j < nb)
    def _history_block():
        ck = kp_ref[0, :, 0, :]
        cv = vp_ref[0, :, 0, :]
        if is_glvq:
            # decode packed words with this head's [d, d] codebook; output
            # columns pad to the (tile-aligned) accumulator width with
            # zeros, matching the zero-padded query columns
            hd_out = acc_ref.shape[-1]
            k = kv_cache.glvq_decode_head(ck, ksc_ref[0, :, 0], kg_ref[0],
                                          kmu_ref[0], glvq, jnp.float32,
                                          hd_out)
            v = kv_cache.glvq_decode_head(cv, vsc_ref[0, :, 0], vg_ref[0],
                                          vmu_ref[0], glvq, jnp.float32,
                                          hd_out)
        elif quant:
            k = kv_cache.kv_dequantize(ck, ksc_ref[0, :, 0], mode,
                                       jnp.float32)
            v = kv_cache.kv_dequantize(cv, vsc_ref[0, :, 0], mode,
                                       jnp.float32)
        else:
            k = ck.astype(jnp.float32)
            v = cv.astype(jnp.float32)
        o = jax.lax.broadcasted_iota(jnp.int32, (r, k.shape[0]), 1)
        in_blk = o < bs                          # tile-padded rows are dead
        if window:
            # ring semantics: which absolute position does ring index
            # j*bs + o hold, given the newest pre-chunk write landed at
            # pos - 1?  (mirrors ring_positions + window_chunk_masks)
            idx = j * bs + o
            lastp = pos_ref[b] - 1
            stored = jnp.where(idx < window,
                               lastp - (lastp - idx) % window, -1)
            valid = in_blk & (stored >= 0) & (stored <= aq) \
                & (stored > aq - window)
        else:
            valid = in_blk & (j * bs + o <= aq)
        _accumulate(k, v, valid)

    if has_chunk:
        kc_ref, vc_ref = rest[0], rest[1]

        @pl.when(j == nb)
        def _chunk_block():
            k = kc_ref[0, 0].astype(jnp.float32)
            v = vc_ref[0, 0].astype(jnp.float32)
            tk = jax.lax.broadcasted_iota(jnp.int32, (r, k.shape[0]), 1)
            _accumulate(k, v, (tk < t) & (tk <= tq))

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = jnp.where(l > 0.0, acc_ref[...] / l,
                                0.0).astype(o_ref.dtype)


@register_attn_backend("pallas")
class _PallasAttn:
    @staticmethod
    def paged_attention(q, cache, table, pos, lens, *, mode, window,
                        k_chunk, v_chunk, kv_backend, out_dtype, glvq=None):
        # lens is part of the uniform backend signature: pad-query outputs
        # are garbage the caller masks (same contract as the chunk step),
        # so the kernel never needs it.  kv_backend routes the unfused
        # gather only — the fused path never gathers.
        del lens, kv_backend
        b, t, h, hd = q.shape
        bs, kv = cache["kp"].shape[1:3]
        nb = table.shape[1]
        n_rep = h // kv
        quant = mode != "paged"
        is_glvq = mode == "paged_glvq"
        if is_glvq and glvq is None:
            glvq = kv_cache.glvq_spec_from_pool(cache)
        has_chunk = k_chunk is not None
        r = n_rep * t

        # [B, T, H, hd] -> [B, KV, n_rep*T, hd]: row rep*T + tq of group g
        # is head g*n_rep + rep at query token tq
        qr = q.reshape(b, t, kv, n_rep, hd).transpose(0, 2, 3, 1, 4) \
              .reshape(b, kv, r, hd)
        kp, vp = cache["kp"], cache["vp"]
        ksc, vsc = cache.get("ksc"), cache.get("vsc")
        kc = vc = None
        if has_chunk:
            kc = k_chunk.transpose(0, 2, 1, 3)           # [B, KV, T, hd]
            vc = v_chunk.transpose(0, 2, 1, 3)

        r_p, t_p, hd_p = r, t, hd
        if kv_cache.tile_pad_enabled():
            # Mosaic wants tile-aligned trailing dims on VMEM blocks; the
            # in-kernel masks (o < bs, tk < t) keep padded rows dead and
            # padded query rows are sliced off the output
            bs_p, hd_p = kv_cache.padded_block_geom(bs, hd)
            r_p = -(-r // 8) * 8
            t_p = -(-t // 8) * 8
            qr = kv_cache.pad_to(kv_cache.pad_to(qr, 2, 8), 3, 128)
            kp = kv_cache.pad_to(kv_cache.pad_to(kp, 1, 8), 3, 128)
            vp = kv_cache.pad_to(kv_cache.pad_to(vp, 1, 8), 3, 128)
            if quant:
                ksc = kv_cache.pad_to(ksc, 1, 8)
                vsc = kv_cache.pad_to(vsc, 1, 8)
            if has_chunk:
                kc = kv_cache.pad_to(kv_cache.pad_to(kc, 2, 8), 3, 128)
                vc = kv_cache.pad_to(kv_cache.pad_to(vc, 2, 8), 3, 128)
        bs_p = kp.shape[1]
        pd_p = kp.shape[3]        # pool last dim: hd_p, or padded words

        # index maps see (grid..., *scalar_prefetch_refs); the table walk is
        # the scalar-prefetch trick: block j of slot i streams pool block
        # table[i, j] through VMEM.  The chunk step (j == nb) re-points the
        # pool specs at the last table block — its data is ignored there.
        def q_spec():
            return pl.BlockSpec((1, 1, r_p, hd_p),
                                lambda i, g, j, tbl, ps: (i, g, 0, 0))

        def pool_spec(nd4: bool):
            if nd4:
                return pl.BlockSpec(
                    (1, bs_p, 1, pd_p),
                    lambda i, g, j, tbl, ps:
                    (tbl[i * nb + jnp.minimum(j, nb - 1)], 0, g, 0))
            return pl.BlockSpec(
                (1, bs_p, 1),
                lambda i, g, j, tbl, ps:
                (tbl[i * nb + jnp.minimum(j, nb - 1)], 0, g))

        def book_spec(arr):
            # per-head codebook: grid step (i, g, j) reads head g's slice
            if arr.ndim == 3:
                return pl.BlockSpec((1,) + arr.shape[1:],
                                    lambda i, g, j, tbl, ps: (g, 0, 0))
            return pl.BlockSpec((1,), lambda i, g, j, tbl, ps: (g,))

        def chunk_spec():
            return pl.BlockSpec((1, 1, t_p, hd_p),
                                lambda i, g, j, tbl, ps: (i, g, 0, 0))

        ins = [qr, kp, vp]
        in_specs = [q_spec(), pool_spec(True), pool_spec(True)]
        if quant:
            ins += [ksc, vsc]
            in_specs += [pool_spec(False), pool_spec(False)]
        if is_glvq:
            books = [cache["kg"], cache["kmu"], cache["vg"], cache["vmu"]]
            ins += books
            in_specs += [book_spec(a) for a in books]
        if has_chunk:
            ins += [kc, vc]
            in_specs += [chunk_spec(), chunk_spec()]

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kv, nb + (1 if has_chunk else 0)),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, r_p, hd_p),
                                   lambda i, g, j, tbl, ps: (i, g, 0, 0)),
            scratch_shapes=[pltpu.VMEM((r_p, 1), jnp.float32),
                            pltpu.VMEM((r_p, 1), jnp.float32),
                            pltpu.VMEM((r_p, hd_p), jnp.float32)],
        )
        out = pl.pallas_call(
            functools.partial(_fused_attn_kernel, mode=mode, window=window,
                              t=t, bs=bs, nb=nb, scale=hd ** -0.5,
                              has_chunk=has_chunk, glvq=glvq),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, kv, r_p, hd_p), out_dtype),
            interpret=not _on_tpu(),
        )(table.reshape(-1).astype(jnp.int32), pos.astype(jnp.int32), *ins)
        if (r_p, hd_p) != (r, hd):
            out = out[:, :, :r, :hd]
        return out.reshape(b, kv, n_rep, t, hd).transpose(0, 3, 1, 2, 4) \
                  .reshape(b, t, h * hd)


# ---------------------------------------------------------------------------
# Public entry point (mode-aware, backend-dispatched, TP-composable)
# ---------------------------------------------------------------------------

def _dispatch(impl, has_chunk, q, pools, table, pos, lens, *chunk, mode,
              window, kv_backend, out_dtype, glvq):
    kc, vc = chunk if has_chunk else (None, None)
    return impl.paged_attention(q, pools, table, pos, lens, mode=mode,
                                window=window, k_chunk=kc, v_chunk=vc,
                                kv_backend=kv_backend, out_dtype=out_dtype,
                                glvq=glvq)


def paged_attention(q, cache, table, pos, lens, *, mode: str,
                    window: int = 0, k_chunk=None, v_chunk=None,
                    kv_backend: Optional[str] = None,
                    backend: Optional[str] = None, mesh=None,
                    out_dtype=None,
                    glvq: Optional[kv_cache.GLVQSpec] = None):
    """Attention over a slot's paged KV history -> out [B, T, H*hd].

    q [B, T, H, hd] post-RoPE queries; ``cache`` this layer's pools
    (``kp``/``vp`` + scales for the quantized kinds); table [B, nb] the
    slot's pool blocks in logical order; pos [B] first absolute position of
    each slot's slab; lens [B] valid slab tokens (outputs of pad queries
    are garbage the caller masks — uniform with the chunk-step contract).

    window > 0 switches to sliding-window ring semantics: the pools hold
    the PRE-append ring (call before ``append_chunk``) and
    ``k_chunk``/``v_chunk`` [B, T, KV, hd] carry the in-flight chunk keys,
    already roundtripped through the cache codec.  window == 0 attends the
    appended history (call after ``append_chunk``), causally masked per
    query position.

    With ``mesh`` (a Mesh with a "model" axis that divides the KV heads)
    the call runs under shard_map: q / pools / chunk keys shard their head
    dim, table / pos / lens replicate, and no collective is needed.
    """
    out_dtype = q.dtype if out_dtype is None else out_dtype
    impl = _ATTN_BACKENDS[resolve_attn_backend(backend)]
    names = ("kp", "vp", "ksc", "vsc")
    if mode == "paged_glvq":
        # decode needs G / mu per head (G^-1 is encode-only, stays behind)
        names += ("kg", "vg", "kmu", "vmu")
        if glvq is None:
            glvq = kv_cache.glvq_spec_from_pool(cache)
    pools = {n: cache[n] for n in names if n in cache}
    has_chunk = k_chunk is not None
    call = functools.partial(_dispatch, impl, has_chunk, mode=mode,
                             window=window, kv_backend=kv_backend,
                             out_dtype=out_dtype, glvq=glvq)
    args = (q, pools, table, pos, lens)
    if has_chunk:
        args += (k_chunk, v_chunk)
    kv = cache["kp"].shape[2]
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate(f"paged_attention[{mode}]"):
        if (mesh is not None and "model" in mesh.axis_names
                and kv % mesh.shape["model"] == 0):
            from repro.optim.compression import shard_map_fn
            smap = shard_map_fn()
            if smap is not None:
                from repro.parallel import sharding
                in_specs, out_spec = sharding.paged_attn_specs(
                    pools, chunked=has_chunk)
                return smap(call, mesh=mesh, in_specs=in_specs,
                            out_specs=out_spec)(*args)
        return call(*args)
