"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import companding, packing

__all__ = ["glvq_matmul_ref", "glvq_dequant_ref", "babai_quantize_ref"]


def glvq_dequant_ref(packed, g, mu, scale, *, bits: int, d: int, n: int,
                     group_size: int = 128) -> jax.Array:
    """uint32 [K, n_words] payload -> f32 W [K, N]."""
    codes = packing.unpack_codes(packed, bits, n)           # [K, N] int32
    k = codes.shape[0]
    n_g = k // group_size
    z = codes.reshape(n_g, group_size, n // d, d).astype(jnp.float32)
    y = jnp.einsum("gsvd,ged->gsve", z, g)                  # w_vec = G z
    y = y.reshape(n_g, group_size, n)
    w = companding.expand(y, mu[:, None, None]) * scale[:, None, None]
    return w.reshape(k, n)


def glvq_matmul_ref(x, packed, g, mu, scale, *, bits: int, d: int, n: int,
                    group_size: int = 128, out_dtype=jnp.float32) -> jax.Array:
    """y = x @ dequant(W);  x [M, K]."""
    w = glvq_dequant_ref(packed, g, mu, scale, bits=bits, d=d, n=n,
                         group_size=group_size)
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def babai_quantize_ref(w, g_inv, mu, scale, *, bits: int, d: int,
                       group_size: int = 128) -> jax.Array:
    """f32 W [K, N] -> int32 codes [K, N] (Babai rounding w/ companding)."""
    k, n = w.shape
    n_g = k // group_size
    wn = w.reshape(n_g, group_size, n) / scale[:, None, None]
    y = companding.compand(wn, mu[:, None, None])
    v = y.reshape(n_g, group_size, n // d, d)
    coords = jnp.einsum("gsvd,ged->gsve", v, g_inv)
    lo = -(2 ** (bits - 1)) if bits > 1 else -1
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 0
    z = jnp.clip(jnp.round(coords), lo, hi).astype(jnp.int32)
    return z.reshape(k, n)
