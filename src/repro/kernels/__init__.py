"""Pallas TPU kernels for GLVQ hot spots (+ jnp oracles in ref.py)."""
from repro.kernels import kv_cache, ops, ref
