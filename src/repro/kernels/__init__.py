"""Pallas TPU kernels for GLVQ hot spots (+ jnp oracles in ref.py)."""
from repro.kernels import ops, ref
