"""Pallas TPU kernel: blocked Babai rounding (quantization time).

    codes[K, N] = clip(round(G^{-1} F_mu(W / scale)))

Grid = (K/group_size, N/Nb). Each step loads one [gs, Nb] weight tile,
compands it, and runs the (gs*Nb/d, d) @ (d, d) coordinate matmul on the MXU
before round+clip. Throughput-critical when quantizing multi-billion-param
models (every Alg. 1 iteration re-rounds the whole layer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, ginv_ref, mu_ref, scale_ref, out_ref, *,
            bits: int, d: int, group_size: int, n_block: int):
    w = w_ref[0].astype(jnp.float32)          # [gs, Nb]
    mu = mu_ref[0]
    scale = scale_ref[0]
    wn = w / scale
    y = jnp.sign(wn) * jnp.log1p(mu * jnp.abs(wn)) / jnp.log1p(mu)
    v = y.reshape(group_size * n_block // d, d)
    ginv = ginv_ref[0]                        # [d, d]
    coords = jax.lax.dot_general(v, ginv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    lo = -(2 ** (bits - 1)) if bits > 1 else -1
    hi = 2 ** (bits - 1) - 1 if bits > 1 else 0
    z = jnp.clip(jnp.round(coords), lo, hi).astype(jnp.int32)
    out_ref[0] = z.reshape(group_size, n_block)


def babai_quantize_pallas(w, g_inv, mu, scale, *, bits: int, d: int,
                          group_size: int = 128, n_block: int = 512,
                          interpret: bool = True):
    """Raw pallas_call; use kernels.ops.babai_quantize for padding."""
    k, n = w.shape
    n_groups = k // group_size
    assert n % n_block == 0 and n_block % d == 0 and k % group_size == 0

    grid = (n_groups, n // n_block)
    kernel = functools.partial(_kernel, bits=bits, d=d, group_size=group_size,
                               n_block=n_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group_size, n_block), lambda kg, j: (kg, 0, j)),
            pl.BlockSpec((1, d, d), lambda kg, j: (kg, 0, 0)),
            pl.BlockSpec((1,), lambda kg, j: (kg,)),
            pl.BlockSpec((1,), lambda kg, j: (kg,)),
        ],
        out_specs=pl.BlockSpec((1, group_size, n_block), lambda kg, j: (kg, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n_groups, group_size, n), jnp.int32),
        interpret=interpret,
    )(w.reshape(n_groups, group_size, n), g_inv, mu, scale).reshape(k, n)
