"""Pallas TPU kernel: fused GLVQ decode + GEMM.

    y[M, N] = x[M, K] @ dequant(packed codes)

The weight never materializes in HBM: each grid step streams one packed-code
tile (b/16 of the bf16 bytes) into VMEM, unpacks b-bit fields with broadcasted
shifts (VPU), decodes the lattice with a (128*Nb/d, d) @ (d, d) matmul (MXU),
applies the inverse mu-law + scale, and accumulates the [Mb, Nb] GEMM tile.

Grid = (M/Mb, Npad/Nb, K/group_size); the K axis is innermost so the f32
accumulator lives in the output VMEM block across the reduction.

Block-size rules (enforced by ops.glvq_matmul):
  * Nb % lcm(per_word, d) == 0  (whole uint32 words + whole lattice vectors)
  * group_size == 128 (paper default; one group per K-step)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import per_word as _per_word


def _kernel(x_ref, packed_ref, g_ref, mu_ref, scale_ref, out_ref, *,
            bits: int, d: int, group_size: int, n_block: int):
    pw = _per_word(bits)
    kg = pl.program_id(2)

    @pl.when(kg == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    words = packed_ref[0]                                  # [gs, Nb/pw] uint32
    shifts = (jnp.arange(pw, dtype=jnp.uint32) * bits)[None, None, :]
    fields = (words[:, :, None] >> shifts) & jnp.uint32((1 << bits) - 1)
    f = fields.reshape(group_size, n_block).astype(jnp.int32)
    z = f - 2 * (f & (1 << (bits - 1)))                    # sign extend
    zf = z.astype(jnp.float32).reshape(group_size * n_block // d, d)

    g = g_ref[0]                                           # [d, d]
    y = jax.lax.dot_general(zf, g, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.reshape(group_size, n_block)

    mu = mu_ref[0]
    scale = scale_ref[0]
    w = jnp.sign(y) * jnp.expm1(jnp.abs(y) * jnp.log1p(mu)) / mu
    w = w * scale                                          # [gs, Nb] f32

    x = x_ref[...].astype(jnp.float32)                     # [Mb, gs]
    out_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def glvq_matmul_pallas(x, packed, g, mu, scale, *, bits: int, d: int,
                       group_size: int = 128, m_block: int = 128,
                       n_block: int = 512, interpret: bool = True):
    """Raw pallas_call; use kernels.ops.glvq_matmul for padding/validation."""
    m, k = x.shape
    n_words = packed.shape[1]
    pw = _per_word(bits)
    n_pad = n_words * pw
    n_groups = k // group_size
    assert n_block % pw == 0 and n_block % d == 0 and n_pad % n_block == 0
    assert m % m_block == 0 and k % group_size == 0
    wb = n_block // pw

    grid = (m // m_block, n_pad // n_block, n_groups)
    kernel = functools.partial(_kernel, bits=bits, d=d, group_size=group_size,
                               n_block=n_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_block, group_size), lambda i, j, kg: (i, kg)),
            pl.BlockSpec((1, group_size, wb),
                         lambda i, j, kg: (kg, 0, j)),
            pl.BlockSpec((1, d, d), lambda i, j, kg: (kg, 0, 0)),
            pl.BlockSpec((1,), lambda i, j, kg: (kg,)),
            pl.BlockSpec((1,), lambda i, j, kg: (kg,)),
        ],
        out_specs=pl.BlockSpec((m_block, n_block), lambda i, j, kg: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), jnp.float32),
        interpret=interpret,
    )(x, packed.reshape(n_groups, group_size, n_words), g, mu, scale)
