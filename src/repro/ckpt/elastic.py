"""Elastic restore: reshard a checkpoint onto whatever mesh is alive.

On restart after node failure the data axis may shrink/grow (model axis is
fixed by the TP layout). Checkpoints store full (unsharded) host arrays, so
elastic restore = restore + device_put with the NEW mesh's NamedShardings.
Batch size per replica is re-derived so the global batch stays constant when
possible (gradient-accumulation factor absorbs non-divisible remainders).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.ckpt.manager import CheckpointManager
from repro.parallel import sharding as shlib

__all__ = ["ElasticPlan", "plan_elastic", "elastic_restore"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_devices: int
    new_devices: int
    per_replica_batch: int
    accum_steps: int            # gradient accumulation to keep global batch

    @property
    def changed(self) -> bool:
        return self.old_devices != self.new_devices


def plan_elastic(global_batch: int, mesh: Mesh,
                 old_devices: Optional[int] = None) -> ElasticPlan:
    n_dp = shlib.dp_size(mesh)
    new_devices = mesh.devices.size
    old = old_devices or new_devices
    # keep global batch fixed; fold any non-divisible remainder into accum
    accum = 1
    per = global_batch // n_dp
    while per * n_dp * accum < global_batch:
        accum += 1
        per = max(global_batch // (n_dp * accum), 1)
    return ElasticPlan(old_devices=old, new_devices=new_devices,
                       per_replica_batch=per, accum_steps=accum)


def elastic_restore(mgr: CheckpointManager, template, mesh: Mesh):
    """Restore latest checkpoint and place it sharded on the (new) mesh."""
    step, host_tree = mgr.restore_latest(template)
    if step is None:
        return None, None
    specs = shlib.param_specs(host_tree, mesh)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    placed = jax.tree.map(put, host_tree, specs,
                          is_leaf=lambda x: isinstance(x, np.ndarray))
    return step, placed
