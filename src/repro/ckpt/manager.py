"""Fault-tolerant checkpointing: atomic commits, keep-k, async save, resume.

Layout:  <dir>/step_<N>/           (committed atomically via tmp-dir rename)
             arrays.npz            (flat path -> np array; one file per host
                                    in multi-process runs: arrays_<proc>.npz)
             META.json             (tree structure, step, wall time)
A checkpoint directory is valid iff the COMMIT marker exists — partial writes
from a killed process are invisible to ``latest_step`` and garbage-collected
on the next save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "flatten_tree", "unflatten_tree"]

_SEP = "||"


def flatten_tree(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def unflatten_tree(template, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.proc = process_index
        self._async_thread: Optional[threading.Thread] = None

    # -- discovery ---------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True):
        arrays = flatten_tree(tree)  # host copies happen on the caller thread

        def _write():
            tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / f"arrays_{self.proc}.npz", **arrays)
            (tmp / "META.json").write_text(json.dumps(
                dict(step=step, time=time.time(), n_leaves=len(arrays))))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            (tmp / "COMMIT").write_text("ok")
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=_write, daemon=True)
            self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
        # clean up orphaned tmp dirs from crashed writers
        for p in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(p, ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, template):
        path = self.dir / f"step_{step}"
        if not (path / "COMMIT").exists():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        arrays = {}
        for f in sorted(path.glob("arrays_*.npz")):
            with np.load(f) as z:
                arrays.update({k: z[k] for k in z.files})
        return unflatten_tree(template, arrays)

    def restore_latest(self, template):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template)
