"""Serving driver: prefill + decode step builders (bf16 or GLVQ-quantized),
with AOT lowering entry points used by the multi-pod dry-run, plus the
``ServingEngine`` CLI (sampled, streamed continuous batching)."""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import quantized
from repro.models import registry
from repro.parallel import sharding
from repro.serving.engine import EngineConfig


def serve_param_shapes(cfg: ModelConfig, *, quant_bits: int = 0,
                       quant_d: int = 16, dtype=jnp.bfloat16):
    """Serving param SDS: bf16 dense, or GLVQ payloads when quant_bits > 0."""
    sds = registry.param_shapes(cfg)
    sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
        if s.dtype == jnp.float32 else s, sds)
    if quant_bits:
        return quantized.quantized_param_shapes(sds, bits=quant_bits,
                                                d=quant_d)
    return sds, None


def make_decode_step(cfg: ModelConfig, engine: EngineConfig):
    """One-token decode closure over an ``EngineConfig``: quantized weights
    dispatch through the QuantTensor engine, a paged ``cache_kind`` routes
    attention history through the KV-cache engine, and ``mesh`` runs
    quantized matmuls tensor-parallel — all per the one config object."""
    def decode_step(params, cache, token, pos):
        return registry.decode_step(params, cache, token, pos, cfg,
                                    engine=engine)
    return decode_step


def make_prefill(cfg: ModelConfig, engine: EngineConfig):
    def prefill(params, batch):
        return registry.forward(params, batch, cfg, dtype=engine.dtype,
                                qmeta=engine.qmeta, unroll=engine.unroll,
                                backend=engine.backend, mesh=engine.mesh)
    return prefill


def lower_decode(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                 quant_bits: int = 0, quant_d: int = 16,
                 dtype=jnp.bfloat16, unroll: int = 1,
                 backend: Optional[str] = None):
    """AOT-lower one decode step against a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    params_sds, qmeta = serve_param_shapes(cfg, quant_bits=quant_bits,
                                           quant_d=quant_d, dtype=dtype)
    cache_sds = registry.cache_specs(cfg, b, s, dtype)
    p_specs = sharding.param_specs(params_sds, mesh, qmeta=qmeta)
    c_specs = sharding.cache_specs_tree(cache_sds, mesh)
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    axes = sharding.dp_axes(mesh)
    bspec = P(axes if len(axes) > 1 else axes[0]) \
        if b % sharding.dp_size(mesh) == 0 else P()
    logits_s = sharding.logits_spec(cfg.vocab, mesh, b)

    ecfg = EngineConfig(dtype=dtype, qmeta=qmeta, unroll=unroll,
                        backend=backend, mesh=mesh)
    step = make_decode_step(cfg, ecfg)
    jitted = jax.jit(
        step,
        in_shardings=sharding.named((p_specs, c_specs, bspec, P()), mesh),
        out_shardings=sharding.named((logits_s, c_specs), mesh),
        donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)
    return lowered


def lower_prefill(cfg: ModelConfig, mesh: Mesh, batch_sds, *,
                  quant_bits: int = 0, quant_d: int = 16,
                  dtype=jnp.bfloat16, batch: int = 0, unroll: int = 1,
                  backend: Optional[str] = None):
    params_sds, qmeta = serve_param_shapes(cfg, quant_bits=quant_bits,
                                           quant_d=quant_d, dtype=dtype)
    p_specs = sharding.param_specs(params_sds, mesh, qmeta=qmeta)
    b_specs = sharding.batch_specs(batch_sds, mesh)
    ecfg = EngineConfig(dtype=dtype, qmeta=qmeta, unroll=unroll,
                        backend=backend, mesh=mesh)
    fn = make_prefill(cfg, ecfg)
    jitted = jax.jit(fn,
                     in_shardings=sharding.named((p_specs, b_specs), mesh),
                     out_shardings=None)
    with mesh:
        lowered = jitted.lower(params_sds, batch_sds)
    return lowered


# ---------------------------------------------------------------------------
# CLI: ServingEngine continuous-batching loop on a tiny model (CPU demo)
# ---------------------------------------------------------------------------

def main(argv=None):
    import json

    import numpy as np

    from repro.serving import kvcache, metrics
    from repro.serving.engine import ServingEngine
    from repro.serving.metrics import log_event
    from repro.serving.policy import FCFSPolicy, TokenBudgetPolicy
    from repro.serving.sampling import SamplingParams

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent batch slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=1,
                    help="chunked prefill width: prompt tokens one engine "
                         "iteration may consume per slot (1 = token-by-"
                         "token baseline; cuts TTFT ~linearly)")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "token_budget"),
                    help="slab-packing policy: fcfs = full chunk width while "
                         "any prompt is in flight; token_budget = Sarathi-"
                         "style cap on total slab tokens per iteration")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="token_budget policy: max valid slab tokens per "
                         "engine iteration (default: batch * chunk-size)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (exact); > 0 samples in-graph")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="sampling seed (deterministic per request / token "
                         "index; independent of chunk width and policy)")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="stop generation when this token id is sampled "
                         "(repeatable)")
    ap.add_argument("--stream", action="store_true",
                    help="print TokenEvents as the engine emits them")
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--backend", default=None,
                    help="quantized-matmul backend "
                         "(pallas_fused | xla_decode | reference)")
    ap.add_argument("--cache", default="dense", choices=kvcache.CACHE_KINDS,
                    help="attention-cache mode: dense per-slot buffers, or "
                         "paged block pools (paged_q8[c] = int8-quantized "
                         "blocks, c = mu-law companded; paged_glvq = "
                         "3-4 bit grouped lattice VQ with learned per-head "
                         "codebooks — see --kv-codebook)")
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--kv-codebook", default=None, metavar="PATH",
                    help="calibrated KV codebook .npz for --cache "
                         "paged_glvq (data.calibration.calibrate_kv / "
                         "save_kv_codebook); omitted = identity lattice "
                         "(plain uniform signed kv-bits grid)")
    ap.add_argument("--kv-bits", type=int, default=4,
                    help="paged_glvq code bits per KV dimension (2-8; "
                         "overridden by the codebook's bits when "
                         "--kv-codebook is given)")
    ap.add_argument("--kv-d", type=int, default=0,
                    help="paged_glvq lattice sub-vector dim (0 = auto: "
                         "largest of 4/2/1 dividing head_dim)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged pool: shared "
                         "prompt blocks are aliased read-only (refcounted, "
                         "copy-on-write at the divergence block) so repeat "
                         "prefixes skip straight to decode")
    ap.add_argument("--prefix-cache-min-blocks", type=int, default=1,
                    help="minimum FULL cached blocks a prompt must match "
                         "before the hit is taken (shorter matches re-"
                         "prefill; raises the sharing threshold)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="give every generated request the same N-token "
                         "system-prompt prefix (the prefix-cache workload; "
                         "0 = fully random prompts)")
    ap.add_argument("--kv-backend", default=None,
                    help="paged-cache kernel backend (pallas | xla)")
    ap.add_argument("--attn-backend", default=None,
                    help="paged-attention kernel backend: pallas = fused "
                         "block-walk + dequant + flash SDPA (one HBM pass), "
                         "xla = gather-then-SDPA oracle (default: pallas on "
                         "TPU, xla elsewhere)")
    ap.add_argument("--logprobs", type=int, default=0,
                    help="attach the sampled token's logprob to every "
                         "TokenEvent plus this many top-k alternatives "
                         "(0 = just the sampled token's)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel size: shard packed payloads over "
                         "the model axis of a (dp, tp) mesh and run every "
                         "quantized matmul per-shard (shard_map)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus text) + /metrics.json "
                         "on this port from a daemon thread (0 = pick free)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the final metrics snapshot as JSON here "
                         "('-' = stdout)")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable telemetry recording entirely "
                         "(EngineConfig.metrics=False)")
    ap.add_argument("--trace-log", default=None, metavar="PATH",
                    help="append one JSONL record per engine iteration "
                         "(slab shape, padding, step timings, events)")
    ap.add_argument("--trace", action="store_true",
                    help="xprof trace annotations around chunk_step / "
                         "paged_attention / kv appends + host spans")
    ap.add_argument("--sync-timing", action="store_true",
                    help="block_until_ready inside the per-iteration "
                         "dispatch timer (honest latencies, no pipelining)")
    ap.add_argument("--debug-checks", action="store_true",
                    help="runtime sanitizer (repro.analysis.runtime): "
                         "in-graph checkify assertions + allocator aliasing "
                         "+ recompile-storm detection; trips raise and count "
                         "serving_debug_check_failures_total")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    qmeta = None
    if args.quant_bits:
        from repro.core.glvq import GLVQConfig
        qcfg = GLVQConfig(d=8, bits=args.quant_bits, iters=8, group_size=32)
        params, qmeta = quantized.quantize_param_tree(params, cfg=qcfg)
        print(f"[serve] quantized weights to {args.quant_bits} bits")
    mesh = None
    if args.tp > 1:
        if jax.device_count() % args.tp:
            raise SystemExit(
                f"--tp {args.tp} needs a device count divisible by it "
                f"(have {jax.device_count()}); hint: "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N")
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(jax.device_count() // args.tp, args.tp)
        if qmeta:
            specs = sharding.param_specs(params, mesh, qmeta=qmeta)
            params = jax.device_put(params, sharding.named(specs, mesh))
            print(f"[serve] tp={args.tp}: packed payloads sharded over "
                  "'model'")
        else:
            print(f"[serve] tp={args.tp}: note — TP only shards quantized "
                  "matmuls; pass --quant-bits to shard the weights")
    kv_codebook = None
    if args.kv_codebook:
        from repro.data.calibration import load_kv_codebook
        kv_codebook = load_kv_codebook(args.kv_codebook)
        log_event("serve", kv_codebook=args.kv_codebook,
                  bits=kv_codebook.bits, d=kv_codebook.d)
    s_cache = max(64, args.prompt_len + args.max_new + 8)
    ecfg = EngineConfig(dtype=jnp.float32, qmeta=qmeta, backend=args.backend,
                        cache_kind=args.cache,
                        kv_bits=args.kv_bits, kv_d=args.kv_d,
                        kv_codebook=kv_codebook,
                        block_size=args.kv_block_size,
                        prefix_cache=args.prefix_cache,
                        prefix_cache_min_blocks=args.prefix_cache_min_blocks,
                        kv_backend=args.kv_backend,
                        attn_backend=args.attn_backend, mesh=mesh,
                        chunk_size=args.chunk_size, s_cache=s_cache,
                        slots=args.batch, topk_logprobs=args.logprobs,
                        metrics=not args.no_metrics, trace=args.trace,
                        sync_timing=args.sync_timing,
                        debug_checks=args.debug_checks)
    if args.policy == "token_budget":
        budget = args.token_budget or args.batch * max(args.chunk_size, 1)
        policy = TokenBudgetPolicy(budget)
        print(f"[serve] policy=token_budget budget={budget} "
              f"widths={policy.program_widths(args.chunk_size)}")
    else:
        policy = FCFSPolicy()
    engine = ServingEngine(params, cfg, ecfg, policy=policy,
                           trace_log=args.trace_log)
    http_server = None
    if args.metrics_port is not None:
        http_server = metrics.serve_http(engine.metrics, args.metrics_port)
        log_event("serve", metrics_port=http_server.server_address[1],
                  endpoints="/metrics,/metrics.json")
    if args.cache != "dense":
        print(f"[serve] cache={args.cache} block_size={args.kv_block_size}")
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed,
                        stop_token_ids=tuple(args.stop_token or ()),
                        max_tokens=args.max_new)
    rng = np.random.default_rng(0)
    shared = list(map(int, rng.integers(
        1, cfg.vocab, min(args.shared_prefix, args.prompt_len))))
    for i in range(args.requests):
        tail = args.prompt_len - len(shared)
        prompt = shared + list(map(int, rng.integers(1, cfg.vocab, tail)))
        engine.submit(prompt, sp, rid=i)
    tm = metrics.Timer()
    n_events = 0
    for ev in engine.stream():
        n_events += 1
        if args.stream:
            tail = f" done[{ev.done_reason}]" if ev.done else ""
            lp = f" lp={ev.logprob:.3f}" if ev.logprob is not None else ""
            print(f"[serve] rid={ev.rid} #{ev.index}: {ev.token}{lp}{tail}")
    dt = tm.total
    done = engine.batcher.finished
    toks = sum(len(r.tokens) for r in done.values())
    assert toks == n_events, "every generated token must stream as an event"
    reasons: Dict[str, int] = {}
    for r in done.values():
        reasons[r.done_reason] = reasons.get(r.done_reason, 0) + 1
    mode = "greedy" if sp.greedy else (
        f"T={sp.temperature} top_k={sp.top_k} top_p={sp.top_p}")
    log_event("serve", requests=len(done), prompt_len=args.prompt_len,
              chunk=engine.batcher.chunk, mode=mode, tokens=toks,
              elapsed_s=dt, tok_per_s=toks / dt,
              done_reasons=reasons)
    pstats = engine.prefix_cache_stats()
    if pstats is not None:
        log_event("serve", prefix_cache=pstats)
    elif args.prefix_cache and args.cache == "dense":
        log_event("serve", note="--prefix-cache needs a paged --cache kind")
    if args.metrics_json:
        snap = json.dumps(engine.metrics_snapshot(), indent=1)
        if args.metrics_json == "-":
            print(snap)
        else:
            with open(args.metrics_json, "w", encoding="utf-8") as f:
                f.write(snap + "\n")
            log_event("serve", metrics_json=args.metrics_json)
    if http_server is not None:
        http_server.shutdown()


if __name__ == "__main__":
    main()
