"""Distributed training driver: step builder + checkpointed CLI loop."""
from __future__ import annotations

import argparse
import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_round
from repro.parallel import sharding
from repro.serving.metrics import Timer, log_event


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    remat: bool = True, dtype=jnp.bfloat16,
                    grad_compression: bool = False, unroll: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, batch, cfg, dtype=dtype, remat=remat,
                                       unroll=unroll)
        )(params)
        if grad_compression:
            # int8 error-feedback compression of the gradient stream
            new_res = {}
            comp = {}
            flat, tree = jax.tree_util.tree_flatten_with_path(grads)
            res_flat = jax.tree_util.tree_leaves(opt_state["ef_residual"])
            outs = [ef_round(g, r) for (_, g), r in zip(flat, res_flat)]
            grads = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
            opt_state = dict(opt_state, ef_residual=jax.tree_util.tree_unflatten(
                tree, [o[1] for o in outs]))
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, params, grads, {k: opt_state[k] for k in ("m", "v", "step")})
        if grad_compression:
            new_opt = dict(new_opt, ef_residual=opt_state["ef_residual"])
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def opt_init(params, *, grad_compression: bool = False):
    state = adamw_init(params)
    if grad_compression:
        state["ef_residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def shardings_for_train(cfg: ModelConfig, mesh: Mesh, params_sds, batch_sds,
                        *, zero: bool = True, grad_compression: bool = False):
    """(in_shardings, out_shardings) pytrees for jit(train_step)."""
    p_specs = sharding.param_specs(params_sds, mesh)
    o_inner = {k: (sharding.zero_shard_specs(p_specs, params_sds, mesh)
                   if zero else p_specs) for k in ("m", "v")}
    o_specs = dict(o_inner, step=P())
    if grad_compression:
        o_specs["ef_residual"] = o_inner["m"]
    b_specs = sharding.batch_specs(batch_sds, mesh)
    metric_specs = dict(lr=P(), grad_norm=P(), loss=P())
    return (p_specs, o_specs, b_specs), (p_specs, o_specs, metric_specs)


def lower_train(cfg: ModelConfig, mesh: Mesh, batch_sds, *,
                zero: bool = True, remat: bool = True,
                grad_compression: bool = False, opt_cfg=None,
                unroll: int = 1):
    """AOT-lower the train step for ShapeDtypeStruct inputs (dry-run path)."""
    opt_cfg = opt_cfg or AdamWConfig(schedule=cfg.lr_schedule
                                     if cfg.lr_schedule != "wsd" else "wsd")
    params_sds = registry.param_shapes(cfg)
    opt_sds = jax.eval_shape(
        functools.partial(opt_init, grad_compression=grad_compression),
        params_sds)
    step = make_train_step(cfg, opt_cfg, remat=remat,
                           grad_compression=grad_compression, unroll=unroll)
    in_sh, out_sh = shardings_for_train(cfg, mesh, params_sds, batch_sds,
                                        zero=zero,
                                        grad_compression=grad_compression)
    jitted = jax.jit(step,
                     in_shardings=sharding.named(in_sh, mesh),
                     out_shardings=sharding.named(out_sh, mesh),
                     donate_argnums=(0, 1))
    with mesh:
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    return lowered


# ---------------------------------------------------------------------------
# CLI: real (small-scale) training with checkpoint/restart
# ---------------------------------------------------------------------------

def main(argv=None):
    from repro.ckpt.manager import CheckpointManager
    from repro.data.synthetic import token_batches

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1),
                          schedule="wsd" if cfg.lr_schedule == "wsd" else "cosine")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_init(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = mgr.latest_step()
    if start is not None:
        params, opt_state = mgr.restore(start, (params, opt_state))
        log_event("train", resumed_from_step=start)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False,
                                      dtype=jnp.float32))
    tm = Timer()
    for step, batch in enumerate(token_batches(cfg, args.batch, args.seq,
                                               args.steps, seed=0)):
        if start is not None and step <= start:
            continue
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            log_event("train", step=step, loss=float(metrics["loss"]),
                      lr=float(metrics["lr"]), elapsed_s=tm.total)
        if step and step % args.ckpt_every == 0:
            mgr.save(step, (params, opt_state))
    mgr.save(args.steps - 1, (params, opt_state))
    log_event("train", done=True, total_s=tm.total)


if __name__ == "__main__":
    main()
