"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HW

ARCH_ORDER = ["minicpm-2b", "nemotron-4-15b", "deepseek-7b", "qwen3-1.7b",
              "qwen2-vl-7b", "olmoe-1b-7b", "dbrx-132b", "whisper-large-v3",
              "recurrentgemma-9b", "mamba2-1.3b", "llama2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir: Path):
    recs = []
    for f in sorted(outdir.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    key = lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER
                     else 99,
                     SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER
                     else 99)
    return sorted(recs, key=key)


def fmt_s(x):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    for unit, scale in (("s", 1), ("ms", 1e-3), ("µs", 1e-6), ("ns", 1e-9)):
        if abs(x) >= scale:
            return f"{x / scale:.2f} {unit}"
    return f"{x:.1e} s"


def dominant(r):
    terms = dict(compute=r["compute_s"], memory=r["memory_s"],
                 collective=r["collective_s"])
    return max(terms, key=terms.get)


def roofline_fraction(rec):
    """compute_term / max(all terms): 1.0 == perfectly compute-bound."""
    r = rec["roofline"]
    top = max(r["compute_s"], r["memory_s"], r["collective_s"])
    if top <= 0:
        return 0.0
    return r["compute_s"] / top


def table(recs, *, mesh="16x16", quant=0):
    rows = ["| arch | shape | compute | memory (HLO) | memory (floor) | "
            "collective | bound | useful-FLOPs | roofline frac |",
            "|---" * 9 + "|"]
    for rec in recs:
        if rec.get("mesh") != mesh or rec.get("quant_bits", 0) != quant:
            continue
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — | "
                        f"skipped (long-context rule) | — | — |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR "
                        f"{rec.get('error', '')[:60]} | | | | | | |")
            continue
        r = rec["roofline"]
        ur = rec.get("useful_flops_ratio")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r.get('memory_floor_s'))} | "
            f"{fmt_s(r['collective_s'])} | {dominant(r)} | "
            f"{ur:.2f} | {roofline_fraction(rec):.4f} |"
            if ur is not None else
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r.get('memory_floor_s'))} | "
            f"{fmt_s(r['collective_s'])} | {dominant(r)} | — | "
            f"{roofline_fraction(rec):.4f} |")
    return "\n".join(rows)


def insert_tables(md_path: Path, outdir: Path):
    recs = load(outdir)
    md = md_path.read_text()
    md = md.replace("<!-- ROOFLINE_TABLE_SINGLE -->", table(recs, mesh="16x16"))
    md = md.replace("<!-- ROOFLINE_TABLE_MULTI -->", table(recs, mesh="2x16x16"))
    md_path.write_text(md)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun_opt")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--quant", type=int, default=0)
    ap.add_argument("--insert", default="",
                    help="path to EXPERIMENTS.md: replace placeholders")
    args = ap.parse_args(argv)
    if args.insert:
        insert_tables(Path(args.insert), Path(args.dir))
        print(f"tables inserted into {args.insert}")
        return
    recs = load(Path(args.dir))
    print(table(recs, mesh=args.mesh, quant=args.quant))


if __name__ == "__main__":
    main()
