"""Production mesh definitions (TPU v5e pods; 256 chips/pod).

Functions, not module constants: importing this module never touches jax
device state (required for the dry-run's forced host-device count).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]

# TPU v5e hardware constants used by the roofline analysis
HW = dict(
    peak_flops_bf16=197e12,     # per chip
    hbm_bw=819e9,               # bytes/s per chip
    ici_bw=50e9,                # bytes/s per link
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small CPU mesh for tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh((data, model), ("data", "model"))
