"""Training supervisor: restart-on-failure around the train loop.

At cluster scale the scheduler restarts failed jobs; this module is the
in-process equivalent used by the launcher and by the fault-tolerance tests:
it resumes from the latest committed checkpoint after any exception, bounded
by ``max_restarts``, with optional deterministic failure injection for tests.
Combined with CheckpointManager's atomic commits this gives exactly-once
training semantics per step (bit-exact resume is covered in
tests/test_substrate.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.synthetic import make_batch, markov_tokens
from repro.launch.train import make_train_step, opt_init
from repro.models import registry
from repro.optim import AdamWConfig

__all__ = ["SimulatedFailure", "supervised_train"]


class SimulatedFailure(RuntimeError):
    """Injected crash (tests / chaos drills)."""


def supervised_train(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                     steps: int, batch: int, seq: int, ckpt_dir: str,
                     ckpt_every: int = 10, max_restarts: int = 5,
                     fail_at: Optional[Iterable[int]] = None,
                     seed: int = 0, dtype=jnp.float32):
    """Run training to completion, restarting from checkpoints on failure.

    ``fail_at``: steps at which to raise SimulatedFailure ONCE each (the
    retry will pass them). Returns (params, opt_state, n_restarts, losses).
    """
    fail_pending = set(fail_at or ())
    mgr = CheckpointManager(ckpt_dir, keep=3)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False, dtype=dtype))
    stream = markov_tokens(cfg.vocab, max(batch * seq * 4, 65_536), seed)
    restarts = 0
    losses = {}

    while True:
        params = registry.init_params(jax.random.PRNGKey(seed), cfg)
        opt_state = opt_init(params)
        start = -1
        latest = mgr.latest_step()
        if latest is not None:
            params, opt_state = mgr.restore(latest, (params, opt_state))
            start = latest
        try:
            for step in range(start + 1, steps):
                if step in fail_pending:
                    fail_pending.discard(step)
                    raise SimulatedFailure(f"injected at step {step}")
                b = make_batch(cfg, batch, seq, seed * 100_003 + step, stream)
                params, opt_state, m = step_fn(params, opt_state, b)
                losses[step] = float(m["loss"])
                if step % ckpt_every == 0 or step == steps - 1:
                    mgr.save(step, (params, opt_state))
            mgr.wait()
            return params, opt_state, restarts, losses
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            # fall through: reload from the latest committed checkpoint
