import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: pjit SPMD
partitioning must succeed for the 16x16 single-pod mesh and the 2x16x16
multi-pod mesh, for every assigned architecture and input shape. Emits
memory_analysis / cost_analysis / collective-byte summaries consumed by the
roofline report (EXPERIMENTS.md).

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first backend init) — which is why this module must not be imported by
tests or benchmarks (they want the real 1-CPU backend).
"""
import argparse
import json
import re
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import HW, make_production_mesh
from repro.models import registry
from repro.serving.metrics import Timer, log_event

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the optimized HLO."""
    stats = {op: dict(count=0, bytes=0.0) for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.groups()
        op = op.rstrip(".0123456789")
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start" or op == c + "-done":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        stats[base]["count"] += 1
        stats[base]["bytes"] += _shape_bytes(shape_str)
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _analyses(lowered, compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        out["cost"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        out["memory"] = {k: int(getattr(ma, k)) for k in keys
                         if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    out["collectives"] = collective_stats(hlo)
    out["hlo_lines"] = hlo.count("\n")
    return out


def roofline_terms(analysis: dict, n_chips: int) -> dict:
    """Three roofline terms (seconds) from the per-device compiled program.

    ``memory_s`` uses XLA's per-device "bytes accessed" — on the CPU dry-run
    backend this is inflated by unfused bf16<->f32 ``convert``/``copy`` ops
    that are free on TPU (MXU-native bf16, aggressive fusion).
    ``memory_floor_s`` is the fusion-ideal bound: every per-device input read
    once + every output written once (argument+output size). The achievable
    TPU number lies between the two; we report both.
    """
    cost = analysis.get("cost", {})
    flops = cost.get("flops", 0.0)              # per-device
    bytes_acc = cost.get("bytes accessed", 0.0)  # per-device
    coll = analysis.get("collectives", {}).get("total_bytes", 0.0)
    mem = analysis.get("memory", {})
    floor_bytes = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0))
    return dict(
        compute_s=flops / HW["peak_flops_bf16"],
        memory_s=bytes_acc / HW["hbm_bw"],
        memory_floor_s=floor_bytes / HW["hbm_bw"],
        collective_s=coll / HW["ici_bw"],
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        floor_bytes_per_device=floor_bytes,
        collective_bytes_per_device=coll,
        n_chips=n_chips,
    )


def _lower_one(cfg, shape, mesh, *, unroll, quant_bits, quant_d, zero, remat,
               grad_compression):
    from repro.launch import serve as serve_lib
    from repro.launch import train as train_lib
    if shape.kind == "train":
        batch_sds = registry.input_specs(cfg, shape)
        return train_lib.lower_train(cfg, mesh, batch_sds, zero=zero,
                                     remat=remat, unroll=unroll,
                                     grad_compression=grad_compression)
    if shape.kind == "prefill":
        batch_sds = registry.input_specs(cfg, shape)
        return serve_lib.lower_prefill(cfg, mesh, batch_sds,
                                       quant_bits=quant_bits,
                                       quant_d=quant_d, unroll=unroll)
    return serve_lib.lower_decode(cfg, mesh, shape, quant_bits=quant_bits,
                                  quant_d=quant_d, unroll=unroll)


def _delta_correct(a1: dict, a2: dict, repeats: int) -> dict:
    """Scan bodies are costed ONCE by XLA's cost analysis regardless of trip
    count (verified on this backend). Compiling at scan-unroll factors 1 and 2
    isolates the per-repeat body cost: total = c1 + (R - 1) * max(c2 - c1, 0).
    """
    out = dict(a1)
    cost = {}
    for k in set(a1.get("cost", {})) | set(a2.get("cost", {})):
        v1 = a1["cost"].get(k, 0.0)
        v2 = a2["cost"].get(k, 0.0)
        if isinstance(v1, str) or isinstance(v2, str):
            continue
        cost[k] = v1 + (repeats - 1) * max(v2 - v1, 0.0)
    out["cost"] = cost
    c1 = a1.get("collectives", {})
    c2 = a2.get("collectives", {})
    coll = {}
    for op in _COLLECTIVES:
        b1 = c1.get(op, {}).get("bytes", 0.0)
        b2 = c2.get(op, {}).get("bytes", 0.0)
        n1 = c1.get(op, {}).get("count", 0)
        n2 = c2.get(op, {}).get("count", 0)
        coll[op] = dict(
            count=n1 + (repeats - 1) * max(n2 - n1, 0),
            bytes=b1 + (repeats - 1) * max(b2 - b1, 0.0))
    coll["total_bytes"] = sum(v["bytes"] for v in coll.values()
                              if isinstance(v, dict))
    out["collectives"] = coll
    out["scan_correction"] = dict(repeats=repeats,
                                  raw_flops=a1.get("cost", {}).get("flops"),
                                  unroll2_flops=a2.get("cost", {}).get("flops"))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant_bits: int = 0, zero: bool = True, remat: bool = True,
             grad_compression: bool = False, quant_d: int = 16) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return analysis dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = registry.supports_shape(cfg, shape)
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               quant_bits=quant_bits, zero=zero, remat=remat,
               grad_compression=grad_compression)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    tm = Timer()
    kw = dict(quant_bits=quant_bits, quant_d=quant_d, zero=zero, remat=remat,
              grad_compression=grad_compression)
    try:
        lowered = _lower_one(cfg, shape, mesh, unroll=1, **kw)
        t_lower = tm.lap()
        compiled = lowered.compile()
        t_compile = tm.lap()
        a1 = _analyses(lowered, compiled)
        # second compile at unroll=2 to expose the per-scan-repeat cost
        lowered2 = _lower_one(cfg, shape, mesh, unroll=2, **kw)
        a2 = _analyses(lowered2, lowered2.compile())
        analysis = _delta_correct(a1, a2, cfg.n_repeats)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), **analysis)
        rec["roofline"] = roofline_terms(analysis, n_chips)
        rec["model_flops_6nd"] = model_flops(cfg, shape)
        r = rec["roofline"]
        total_flops = r["flops_per_device"] * n_chips
        rec["useful_flops_ratio"] = (rec["model_flops_6nd"] / total_flops
                                     if total_flops else None)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.enc_layers:
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // cfg.frontend_stride)
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all' (assigned 10), or comma list")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--quant-d", type=int, default=16)
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = list(ASSIGNED) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}" \
                    + (f"_q{args.quant_bits}" if args.quant_bits else "") \
                    + ("_nozero" if args.no_zero else "") \
                    + ("_gc" if args.grad_compression else "")
                rec = run_cell(arch, shape, multi_pod=mp,
                               quant_bits=args.quant_bits,
                               quant_d=args.quant_d,
                               zero=not args.no_zero,
                               remat=not args.no_remat,
                               grad_compression=args.grad_compression)
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f"comp {r['compute_s']:.2e}s mem {r['memory_s']:.2e}s "
                             f"coll {r['collective_s']:.2e}s "
                             f"[{rec['compile_s']:.0f}s compile]")
                elif st == "error":
                    extra = rec["error"][:160]
                print(f"[dryrun] {tag:55s} {st:7s} {extra}", flush=True)
    log_event("dryrun", ok=n_ok, skipped=n_skip, errors=n_err)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
