"""Kernel + engine trace hooks: xprof annotations and a JSONL event log.

Two host-cheap instrumentation primitives, both gated by one process-level
flag (``EngineConfig.trace=True`` or ``REPRO_TRACE=1``) so the default
serving path pays nothing:

  * ``annotate(name)`` — wraps a *traced* region (kernel dispatch inside a
    jitted step) in ``jax.named_scope``: the scope lands in the op metadata,
    so an xprof capture attributes HBM/compute time to named kernels
    (``chunk_step``, ``paged_attention[...]``, ``kv_append_chunk[...]``).
    It runs only while JAX is tracing a new program shape — zero per-step
    cost once compiled, and it never changes the computation.
  * ``host_span(name)`` — wraps a *host* region (one scheduler iteration)
    in ``jax.profiler.TraceAnnotation`` so the same capture shows where
    host wall-clock went between dispatches.

The kernel modules import this lazily at call time (tracing only), keeping
``repro.kernels`` import-light and cycle-free.

``TraceLog`` is the structured per-iteration event log behind
``launch/serve.py --trace-log``: one JSON object per line, schema documented
in the README Observability section.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Dict, IO, Optional, Union

__all__ = ["enabled", "enable", "annotate", "host_span", "TraceLog"]

_ENV_TRACE = "REPRO_TRACE"
_enabled: Optional[bool] = None        # None -> read the env on first use


def enabled() -> bool:
    global _enabled
    if _enabled is None:
        _enabled = os.environ.get(_ENV_TRACE, "") not in ("", "0")
    return _enabled


def enable(flag: bool = True):
    """Turn trace annotations on/off process-wide (EngineConfig.trace does
    this at batcher construction).  Off overrides the env."""
    global _enabled
    _enabled = bool(flag)


def annotate(name: str):
    """Named scope for a traced region; no-op context when tracing is off."""
    if not enabled():
        return contextlib.nullcontext()
    import jax
    return jax.named_scope(name)


def host_span(name: str):
    """Host-timeline span (xprof TraceAnnotation); no-op when off."""
    if not enabled():
        return contextlib.nullcontext()
    import jax
    return jax.profiler.TraceAnnotation(name)


class TraceLog:
    """Append-only JSONL event sink (one dict per line, flushed per write
    so a killed server loses at most the in-flight line).

    The scheduler writes one record per engine iteration; anything
    JSON-serializable can ride along.  A ``ts`` wall-clock field is stamped
    here so every consumer sees the same clock."""

    def __init__(self, path_or_file: Union[str, "os.PathLike[str]", IO[str]]):
        if hasattr(path_or_file, "write"):
            self._f: IO[str] = path_or_file          # type: ignore[assignment]
            self._owns = False
            self.path = getattr(path_or_file, "name", None)
        else:
            self.path = os.fspath(path_or_file)
            self._f = open(self.path, "a", encoding="utf-8")
            self._owns = True
        self.records = 0

    def write(self, record: Dict[str, Any]):
        rec = dict(record)
        rec.setdefault("ts", time.time())
        self._f.write(json.dumps(rec, default=str) + "\n")
        self._f.flush()
        self.records += 1

    def close(self):
        if self._owns:
            self._f.close()

    def __enter__(self) -> "TraceLog":
        return self

    def __exit__(self, *exc):
        self.close()
        return False
