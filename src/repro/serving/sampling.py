"""Per-request sampling: ``SamplingParams`` + batched in-graph token sampling.

``SamplingParams`` travels with each ``Request``; the scheduler flattens the
live slots' params into small per-slot arrays (temperature / top-k / top-p /
seed / token-index) every engine iteration and ``sample_tokens`` runs INSIDE
the compiled serving step, directly on the chunk-final logits.  The host loop
therefore receives ``[B]`` sampled token ids instead of ``[B, vocab]``
logits — at tensor parallelism the full-vocab tensor never crosses the host
boundary — and changing a request's sampling params never recompiles (they
are traced values, not static arguments).

Determinism: the PRNG key for a request's ``i``-th generated token is
``fold_in(PRNGKey(seed), i)`` — a pure function of (seed, token index), NOT
of how many engine iterations ran before it.  Carried split-per-step key
state would consume different amounts of randomness under different chunk
widths or scheduler policies; the stateless derivation makes a fixed seed
reproduce the same token stream across chunk widths, slab packings, backends,
and TP meshes (the sampled stream only depends on the logits, which the
chunk-parity suite pins down).

``temperature=0`` lowers to a plain ``argmax`` of the raw logits — bit-for-bit
the greedy path — so greedy serving is just the default ``SamplingParams()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "sample_tokens", "token_logprobs"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    temperature: 0 = greedy argmax (exact); > 0 scales the logits before
        gumbel sampling.
    top_k: keep only the k highest-probability tokens (0 = off).
    top_p: keep the smallest prefix of the sorted distribution whose
        cumulative probability reaches p (1.0 = off; the most-likely token
        is always kept).
    seed: PRNG seed for this request's token stream; ``None`` derives a
        deterministic per-request default from the request id.
    stop_token_ids: generation ends when one of these ids is sampled (the
        stop token is kept as the last element of ``Request.tokens`` and the
        finished request carries ``done_reason="stop_token"``).
    max_tokens: generation cap for this request; ``None`` falls back to the
        request's ``max_new`` (and ultimately the cache capacity).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()
    max_tokens: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def _token_keys(seeds, idx):
    """[B] seeds x [B] token indices -> [B] PRNG keys, statelessly."""
    def one(seed, i):
        return jax.random.fold_in(jax.random.PRNGKey(seed), i)
    return jax.vmap(one)(seeds, idx)


def sample_tokens(logits, seeds, idx, temps, top_ks, top_ps):
    """Batched per-slot sampling, traced inside the serving step.

    logits [B, V] f32 (each slot's chunk-final row); seeds [B] i32; idx [B]
    i32 index of the token being sampled in each request's generated stream;
    temps [B] f32; top_ks [B] i32 (0 = off); top_ps [B] f32 (1 = off).
    Returns [B] i32 token ids.  Rows with ``temps == 0`` are exact argmax
    (identical to the host-side greedy path); the rest draw one gumbel
    top-k/top-p sample.  All params are traced, so request churn never
    changes the compiled program.
    """
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled(_):
        safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
        scaled = logits / safe_t
        # one descending sort serves both filters (top-k keeps the k
        # largest, so its mask is a prefix of the same order top-p cuts)
        sorted_l = jnp.sort(scaled, axis=-1)[:, ::-1]

        k = jnp.clip(top_ks, 0, v)
        kth = jnp.take_along_axis(sorted_l,
                                  jnp.maximum(k - 1, 0)[:, None], axis=-1)
        k_off = (k == 0)[:, None]
        masked = jnp.where(k_off | (scaled >= kth), scaled, -jnp.inf)
        sorted_m = jnp.where(k_off | (sorted_l >= kth), sorted_l, -jnp.inf)

        probs = jax.nn.softmax(sorted_m, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # exclusive-prefix rule: token j survives iff the mass BEFORE it is
        # still under top_p — the most likely token always survives, and
        # the kept set is the smallest prefix reaching p
        keep = (cum - probs) < top_ps[:, None]
        n_keep = jnp.maximum(jnp.sum(keep, axis=-1), 1)
        thr = jnp.take_along_axis(sorted_m, (n_keep - 1)[:, None], axis=-1)
        masked = jnp.where(masked >= thr, masked, -jnp.inf)

        keys = _token_keys(seeds, idx)
        u = jax.vmap(lambda key: jax.random.uniform(
            key, (v,), minval=jnp.finfo(jnp.float32).tiny, maxval=1.0))(keys)
        gumbel = -jnp.log(-jnp.log(u))
        return jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)

    # all-greedy iterations (the default) skip the sort/softmax/RNG
    # machinery entirely — at a real vocab that is the decode hot path
    sampled = jax.lax.cond(jnp.any(temps > 0), _sampled,
                           lambda _: greedy_tok, operand=None)
    return jnp.where(temps > 0, sampled, greedy_tok)


def token_logprobs(logits, tokens, n_top: int = 0):
    """In-graph logprob gather for the sampled tokens.

    logits [B, V] raw chunk-final logits; tokens [B] i32 the sampled ids;
    ``n_top`` (static) adds the top-k alternatives.  Returns (lp [B] f32,
    top_vals [B, n_top] f32, top_ids [B, n_top] i32) — still ``[B]``-scale,
    so riding the existing host boundary costs nothing vocab-sized.

    Reported logprobs are under the MODEL distribution (log-softmax of the
    raw logits, before temperature / top-k / top-p shaping): they stay
    comparable across sampling params and match teacher-forced NLL.  One
    logsumexp reduction, no [B, V] softmax materialization."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tokens[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
    lp = picked - lse
    b = logits.shape[0]
    if n_top:
        tv, ti = jax.lax.top_k(logits, n_top)
        return lp, tv - lse[:, None], ti.astype(jnp.int32)
    return (lp, jnp.zeros((b, 0), jnp.float32), jnp.zeros((b, 0), jnp.int32))
