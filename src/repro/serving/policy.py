"""Scheduler policies: slot admission + slab packing, pulled out of the
continuous batcher so serving behavior is pluggable without touching the
engine step.

A policy decides, per engine iteration, (a) which queued requests claim free
slots (``assign``) and (b) the token-slab shape: the slab width ``T`` and how
many tokens each slot consumes from it (``widths``).  The batcher turns that
plan into one ``[B, T]`` chunk-step call; a slot given 0 tokens simply rides
along fully masked (lens = 0), so deferring a slot is free.

Compiled-shape discipline: every distinct ``T`` a policy emits is one XLA
program in the serving step's jit cache.  ``program_widths`` declares the
full family up front — ``FCFSPolicy`` compiles at most {1, chunk};
``TokenBudgetPolicy`` picks T from a small fixed ladder, so its family is
bounded by the ladder length no matter how load fluctuates (asserted by the
compile-count spy test).
"""
from __future__ import annotations

from typing import Deque, List, Optional, Sequence, Tuple

__all__ = ["SchedulerPolicy", "FCFSPolicy", "TokenBudgetPolicy",
           "default_ladder"]


def default_ladder(chunk: int) -> Tuple[int, ...]:
    """Powers of two up to ``chunk``, always ending at ``chunk`` itself."""
    chunk = max(1, int(chunk))
    ladder = [1]
    while ladder[-1] * 2 < chunk:
        ladder.append(ladder[-1] * 2)
    if ladder[-1] != chunk:
        ladder.append(chunk)
    return tuple(ladder)


class SchedulerPolicy:
    """Base policy: FCFS admission; packing left to subclasses.

    ``remaining`` below is the per-slot prompt view: ``None`` = free slot,
    ``0`` = decoding (consumes exactly 1 token), ``n > 0`` = still has n
    prompt tokens to prefill.  With the prefix cache on, a cache hit
    pre-advances the slot's prompt cursor to the reused token count at
    claim time, so ``remaining`` — and therefore every budget/packing
    decision below — already counts only the un-cached remainder.
    """

    name = "base"

    def assign(self, slots, queue: Deque) -> List[Tuple[int, object]]:
        """Claim free slots from the queue head; returns (slot, request)."""
        out = []
        for i, s in enumerate(slots):
            if s.free and queue:
                out.append((i, queue.popleft()))
        return out

    def widths(self, remaining: Sequence[Optional[int]],
               chunk: int) -> Tuple[int, List[int]]:
        """-> (slab width T, per-slot token takes, each in [0, T])."""
        raise NotImplementedError

    def program_widths(self, chunk: int) -> Tuple[int, ...]:
        """Every slab width this policy can emit (the compiled-shape family)."""
        raise NotImplementedError


def _takes(remaining: Sequence[Optional[int]], t: int) -> List[int]:
    """Greedy per-slot consumption at slab width ``t``."""
    return [0 if r is None else (min(r, t) if r > 0 else 1)
            for r in remaining]


class FCFSPolicy(SchedulerPolicy):
    """PR-4 behavior: while ANY prompt is in flight every iteration runs at
    the full chunk width (decode slots ride along at 1 valid token); pure
    decode runs at T = 1.  Exactly two compiled shapes."""

    name = "fcfs"

    def widths(self, remaining, chunk):
        prefilling = any(r is not None and r > 0 for r in remaining)
        t = chunk if (prefilling and chunk > 1) else 1
        return t, _takes(remaining, t)

    def program_widths(self, chunk):
        return (1,) if chunk <= 1 else (1, chunk)


class TokenBudgetPolicy(SchedulerPolicy):
    """Sarathi-style packer: cap TOTAL valid slab tokens per iteration.

    Each iteration picks the widest ladder width ``t`` whose greedy takes sum
    to at most ``token_budget`` — a lone prefill gets the whole budget as one
    wide slab (better TTFT than a fixed conservative chunk), while a prefill
    sharing the engine with decode slots is throttled so decode inter-token
    latency stays bounded.  Widths come from a small fixed ladder, so the
    compiled program family is bounded by ``len(ladder)`` regardless of how
    requests arrive.

    When even ``t = 1`` exceeds the budget (more live slots than budget
    tokens) the iteration still runs at T = 1: every active slot must
    advance, so the budget is a packing target, not an admission limit.
    """

    name = "token_budget"

    def __init__(self, token_budget: int,
                 ladder: Optional[Sequence[int]] = None):
        if token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.token_budget = int(token_budget)
        self.ladder = tuple(sorted(set(int(w) for w in ladder))) \
            if ladder else None
        if self.ladder and self.ladder[0] < 1:
            raise ValueError(f"ladder widths must be >= 1, got {self.ladder}")

    def _rungs(self, chunk: int) -> Tuple[int, ...]:
        ladder = self.ladder or default_ladder(chunk)
        return tuple(w for w in ladder if w <= chunk) or (1,)

    def widths(self, remaining, chunk):
        prefill = [r for r in remaining if r is not None and r > 0]
        if not prefill:
            return 1, _takes(remaining, 1)
        t = 1
        for w in self._rungs(chunk):            # ascending; takes-sum is
            if sum(_takes(remaining, w)) <= self.token_budget:
                t = w                           # monotone in w, keep last fit
            else:
                break
            if w >= max(prefill):
                break                           # wider rungs add pure padding
        return t, _takes(remaining, t)

    def program_widths(self, chunk):
        return tuple(sorted(set((1,) + self._rungs(chunk))))
