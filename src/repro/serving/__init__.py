from repro.serving import kvcache
from repro.serving.scheduler import ContinuousBatcher, Request
