from repro.serving import kvcache
from repro.serving.engine import (EngineConfig, RequestHandle, ServingEngine,
                                  TokenEvent)
from repro.serving.kvcache import BlockAllocator, PrefixCache
from repro.serving.policy import FCFSPolicy, SchedulerPolicy, TokenBudgetPolicy
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (DONE_CACHE_FULL, DONE_LENGTH, DONE_STOP,
                                     ContinuousBatcher, Request)
