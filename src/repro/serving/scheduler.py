"""Continuous-batching serving scheduler with chunked prefill + in-graph
sampling.

Hybrid (Sarathi-style) continuous batching: a fixed number of batch slots
advance through ONE variable-width engine step (``registry.chunk_step``) per
iteration.  What each iteration looks like is a ``SchedulerPolicy`` decision
(``serving.policy``): ``FCFSPolicy`` reproduces the classic two-shape
behavior (T = chunk while any prompt is in flight, T = 1 steady-state);
``TokenBudgetPolicy`` caps total valid slab tokens per iteration with widths
drawn from a fixed ladder, so the compiled program family stays bounded.

Sampling happens INSIDE the compiled step (``serving.sampling``): the
per-slot ``SamplingParams`` flatten into small traced arrays, the chunk-final
logits are sampled on device, and only ``[B]`` token ids reach the host —
under tensor parallelism the full-vocab logits never cross the host boundary.
``temperature=0`` (the default) is bit-for-bit the greedy path.

Idle slots carry ``lens = 0``: every KV write, recurrent-state update, and
logit of their pad positions is masked inside the chunk step.  Recurrent
families (mamba2 / rglru / hybrid) integrate state every step, so the
scheduler zeroes a slot's recurrent state when a new request claims it
(``registry.reset_slot``) — slot churn cannot leak one request's state into
the next.

Every execution knob (dtype / qmeta / backend / mesh, cache_kind /
block_size / kv_backend / s_cache, slots / chunk_size / stop tokens) lives
in one ``EngineConfig`` (``serving.engine``).  The PR-4 loose-kwarg
constructor keeps working through a deprecation shim.

Cache modes (``cache_kind``): ``dense`` keeps per-slot max-length K/V
buffers; ``paged`` / ``paged_q8`` / ``paged_q8c`` switch every attention
layer to shared block pools (``serving.kvcache``) — the scheduler grants a
slot ALL the blocks its chunk will touch up front and returns them to the
free list when the request retires, so resident cache bytes track live
tokens instead of worst-case length.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import runtime as debug_runtime
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import kvcache, trace
from repro.serving.engine import EngineConfig, TokenEvent
from repro.serving.metrics import MetricsRegistry
from repro.serving.policy import FCFSPolicy, SchedulerPolicy
from repro.serving.sampling import (SamplingParams, sample_tokens,
                                    token_logprobs)

__all__ = ["Request", "ContinuousBatcher",
           "DONE_LENGTH", "DONE_STOP", "DONE_CACHE_FULL"]

DONE_LENGTH = "length"            # hit the request's token cap
DONE_STOP = "stop_token"          # sampled a stop id
DONE_CACHE_FULL = "cache_full"    # no cache positions left for this slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    done: bool = False
    params: Optional[SamplingParams] = None   # None -> batcher default
    done_reason: Optional[str] = None
    # lifecycle timestamps (perf_counter clock), stamped by the batcher;
    # the metrics histograms (queue wait / TTFT / inter-token) read these
    t_submit: Optional[float] = None
    t_first_sched: Optional[float] = None
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    prompt_cursor: int = 0      # how many prompt tokens already consumed

    @property
    def free(self) -> bool:
        return self.req is None


def _local_ring(cfg: ModelConfig, s_cache: int) -> Optional[int]:
    """Smallest sliding-window ring length in the stack, if any."""
    kinds = tuple(cfg.scan_unit) + tuple(cfg.scan_tail)
    if cfg.window and any(k == "attn_local" for k in kinds):
        return min(cfg.window, s_cache)
    return None


# legacy ContinuousBatcher(**kwargs) keys -> EngineConfig fields (greedy is
# handled separately: it shapes default_params, not the config)
_LEGACY_KEYS = ("slots", "s_cache", "dtype", "qmeta", "backend", "pad_token",
                "cache_kind", "block_size", "num_blocks", "kv_backend",
                "attn_backend", "mesh", "chunk_size")
_LEGACY_DEFAULT_S_CACHE = 64
_LEGACY_DEFAULT_DTYPE = jnp.float32


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig,
                 engine: Optional[EngineConfig] = None, *,
                 policy: Optional[SchedulerPolicy] = None,
                 default_params: Optional[SamplingParams] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 trace_log=None,
                 **legacy):
        """``engine`` consolidates every execution knob (see
        ``serving.engine.EngineConfig``); ``policy`` plugs the slab-packing
        strategy (default ``FCFSPolicy``); ``default_params`` is the
        ``SamplingParams`` applied to requests that carry none (default:
        greedy).  ``metrics`` injects a shared ``MetricsRegistry`` (one is
        created when None and ``engine.metrics`` is on); ``trace_log`` is a
        ``serving.trace.TraceLog`` (or file path) receiving one structured
        record per engine iteration.  The PR-4 loose-kwarg signature
        (``ContinuousBatcher(params, cfg, slots=..., qmeta=..., ...)``)
        still works through a deprecation shim."""
        greedy = legacy.pop("greedy", None)
        if legacy or greedy is not None:
            if engine is not None:
                raise TypeError(
                    "pass either an EngineConfig or the legacy loose kwargs,"
                    f" not both (got EngineConfig plus {sorted(legacy)})")
            unknown = sorted(set(legacy) - set(_LEGACY_KEYS))
            if unknown:
                raise TypeError(f"unknown ContinuousBatcher kwargs {unknown}; "
                                f"legacy kwargs are {_LEGACY_KEYS}")
            warnings.warn(
                "ContinuousBatcher(**loose_kwargs) is deprecated; pass "
                "ContinuousBatcher(params, cfg, EngineConfig(...)) instead",
                DeprecationWarning, stacklevel=2)
            legacy.setdefault("s_cache", _LEGACY_DEFAULT_S_CACHE)
            legacy.setdefault("dtype", _LEGACY_DEFAULT_DTYPE)
            engine = EngineConfig(**legacy)
            if greedy is False and default_params is None:
                # the old greedy=False flag crashed outright (host argmax was
                # the only mode); it now means "actually sample".  seed stays
                # None so each request falls back to its rid-derived stream —
                # concurrent requests must not draw correlated noise
                default_params = SamplingParams(temperature=1.0)
        if engine is None:
            engine = EngineConfig(s_cache=_LEGACY_DEFAULT_S_CACHE,
                                  dtype=_LEGACY_DEFAULT_DTYPE)

        self.params = params
        self.cfg = cfg
        self.policy = policy if policy is not None else FCFSPolicy()
        self.default_params = default_params if default_params is not None \
            else SamplingParams()
        s_cache = engine.s_cache if engine.s_cache is not None \
            else _LEGACY_DEFAULT_S_CACHE
        self.s_cache = s_cache
        self.pad = engine.pad_token
        self.cache_kind = engine.cache_kind
        chunk = max(1, int(engine.chunk_size))
        ring = _local_ring(cfg, s_cache)
        if ring is not None:
            chunk = min(chunk, ring)
        self.chunk = min(chunk, s_cache)
        self.slots = [_Slot() for _ in range(engine.slots)]
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self.pages: Optional[kvcache.SlotPages] = None
        self.prefix: Optional[kvcache.PrefixCache] = None
        num_blocks = engine.num_blocks
        if engine.cache_kind != "dense":
            layout = kvcache.PageLayout.plan(s_cache, engine.slots,
                                             engine.block_size, num_blocks)
            self.pages = kvcache.SlotPages(engine.slots, layout)
            num_blocks = layout.num_blocks
            if engine.prefix_cache:
                # sharing is only sound when every cached position is
                # reconstructable from the aliased blocks alone: recurrent
                # state lives outside the pool and sliding-window rings
                # OVERWRITE shared positions, so such stacks always miss
                shareable = not registry.has_recurrent(cfg) \
                    and _local_ring(cfg, s_cache) is None
                if shareable:
                    self.prefix = kvcache.PrefixCache(
                        self.pages.alloc, layout.block_size,
                        min_blocks=engine.prefix_cache_min_blocks)
                # the CoW copy runs as ONE compiled program for any
                # (src, dst) pair; donation lets XLA update the pools
                # in place instead of cloning every layer per copy
                self._copy_block = jax.jit(kvcache.copy_block,
                                           donate_argnums=(0,))
        # the stored config carries the RESOLVED s_cache / num_blocks so the
        # compiled step and the cache agree on geometry
        self.engine_config = engine.replace(s_cache=s_cache,
                                            num_blocks=num_blocks)
        self.cache = registry.cache_init(cfg, engine.slots,
                                         engine=self.engine_config)
        if self.prefix is not None:
            # pre-pay the CoW program's one compile with a no-op
            # scratch->scratch copy, so the first real mid-block
            # divergence doesn't stall a serving iteration on a trace
            self.cache = self._copy_block(self.cache, 0, 0)
        self._recurrent = registry.has_recurrent(cfg)
        self._reset = jax.jit(
            lambda c, i: registry.reset_slot(c, cfg, i))
        if engine.trace:
            trace.enable(True)
        self._init_telemetry(metrics, trace_log)
        # ONE jitted program family over the policy's slab widths; sampling
        # is traced into the same program, so only [B] ids reach the host
        ecfg = self.engine_config

        def _step_fn(p, c, toks, poss, lens, seeds, sidx, temps, tks, tps):
            # this body only runs while JAX traces a NEW slab shape, so it
            # is the compile-event hook: one increment per compiled program
            # (the spy tests intercept registry.chunk_step the same way)
            self._compiles += 1
            logits, c = registry.chunk_step(p, c, toks, poss, lens, cfg,
                                            engine=ecfg)
            toks_out = sample_tokens(logits, seeds, sidx, temps, tks, tps)
            lp, tv, ti = token_logprobs(logits, toks_out,
                                        n_top=ecfg.topk_logprobs)
            return (toks_out, lp, tv, ti), c

        # raw step closure kept visible: benchmarks/serving.py traces it to
        # assert debug_checks=False leaves the compiled graph untouched
        self._step_fn = _step_fn
        self._step = jax.jit(_step_fn)
        self._debug = bool(ecfg.debug_checks)
        if self._debug:
            # sanitizer layer (repro.analysis.runtime): the checked step is
            # a SEPARATE jit — the plain self._step above stays pristine
            debug_runtime.check_payload_alignment(self.params, ecfg.qmeta)
            self._checked_step = debug_runtime.make_checked_step(
                _step_fn, s_cache=self.s_cache,
                num_blocks=ecfg.num_blocks if self.pages is not None
                else None)
            widths = getattr(self.policy, "program_widths", None)
            n_programs = len(widths(self.chunk)) if callable(widths) else 4
            # x2 + 2: weak-type promotion on the first call and the warmup
            # trace of each rung may legitimately double-compile
            self._recompile_monitor = debug_runtime.RecompileMonitor(
                2 * n_programs + 2)

    # -- telemetry ------------------------------------------------------------
    def _init_telemetry(self, metrics: Optional[MetricsRegistry], trace_log):
        """Resolve the metrics registry + trace log and pre-bind the
        per-event metric handles (so the hot step path never pays a
        name/label lookup).  With ``engine.metrics`` off nothing is ever
        recorded — ``self.metrics`` stays an empty registry."""
        ecfg = self.engine_config
        self._compiles = 0                     # bumped by the trace hook
        self._iterations = 0
        if not isinstance(trace_log, trace.TraceLog) and trace_log is not None:
            trace_log = trace.TraceLog(trace_log)
        self._trace_log = trace_log
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if not ecfg.metrics:
            self._mx = None
            return
        mx = self.metrics
        self._mx = mx
        self._m_submitted = mx.counter(
            "serving_requests_submitted_total",
            "requests accepted by submit()")
        self._m_tokens = mx.counter(
            "serving_tokens_generated_total", "tokens sampled and emitted")
        self._m_queue_wait = mx.histogram(
            "serving_queue_wait_seconds", "submit -> first scheduled")
        self._m_ttft = mx.histogram(
            "serving_ttft_seconds", "submit -> first generated token")
        self._m_itl = mx.histogram(
            "serving_inter_token_seconds", "gap between a request's tokens")
        self._m_step = mx.histogram(
            "serving_step_seconds", "one whole engine iteration (host)")
        self._m_dispatch = mx.histogram(
            "serving_dispatch_seconds",
            "jitted step dispatch (block_until_ready'd when sync_timing)")
        self._m_valid = mx.counter("serving_slab_tokens_total",
                                   "slab positions by kind", kind="valid")
        self._m_pad = mx.counter("serving_slab_tokens_total", kind="pad")
        self._m_pad_frac = mx.gauge(
            "serving_slab_padded_fraction",
            "padded fraction of the last iteration's [B, T] slab")
        self._m_compile = mx.counter(
            "serving_compile_events_total",
            "distinct slab programs traced (one per compile)")
        self._policy_name = getattr(self.policy, "name",
                                    type(self.policy).__name__)
        self._m_width: Dict[int, object] = {}   # iteration counter per rung
        self._dtype_bytes = jnp.dtype(ecfg.dtype).itemsize
        if self.pages is not None:
            self._m_blocks_used = mx.gauge(
                "kv_blocks_used", "live pool blocks (excl. scratch)")
            self._m_blocks_free = mx.gauge("kv_blocks_free")
            self._m_blocks_hw = mx.gauge(
                "kv_blocks_high_water", "max blocks ever live at once")
            self._m_allocs = mx.counter("kv_block_allocs_total")
            self._m_frees = mx.counter("kv_block_frees_total")
            self._m_dfree = mx.counter(
                "kv_block_double_free_rejected_total",
                "frees the double-free guard refused")
            self._m_exhaust = mx.counter(
                "kv_pool_exhausted_total", "allocs that found no free block")
        if self.prefix is not None:
            self._m_pfx_hits = mx.counter(
                "serving_prefix_cache_hits_total",
                "claims that aliased at least min_blocks cached blocks")
            self._m_pfx_miss = mx.counter(
                "serving_prefix_cache_misses_total",
                "claims with no usable cached prefix")
            self._m_pfx_tokens = mx.counter(
                "serving_prefix_tokens_reused_total",
                "prompt tokens whose prefill was skipped via cached blocks")
            self._m_pfx_cow = mx.counter(
                "serving_prefix_cow_copies_total",
                "copy-on-write block copies (mid-block divergence)")
            self._m_pfx_evict = mx.counter(
                "serving_prefix_evictions_total",
                "cached blocks evicted (LRU) under pool pressure")
            self._m_pfx_resident = mx.gauge(
                "serving_prefix_shared_resident_blocks",
                "pool blocks the radix index keeps resident "
                "(live-shared + refcount-0 cached)")
        self._m_resident = mx.gauge(
            "kv_cache_resident_bytes",
            "modeled resident cache bytes over live slots "
            "(serving.kvcache.cache_bytes)", kind=ecfg.cache_kind)
        # the byte-economy gauges carry a host label: under multi-process
        # serving each process exports its own resident-byte series
        host = str(jax.process_index())
        self._m_bpt = mx.gauge(
            "serving_kv_bytes_per_token",
            "modeled resident cache bytes per live stored token "
            "(serving.kvcache.cache_bytes over current slot positions)",
            kind=ecfg.cache_kind, host=host)
        self._m_book_bytes = mx.gauge(
            "serving_kv_codebook_bytes",
            "resident GLVQ codebook overhead (f32 generation matrices "
            "shared by all slots; 0 for non-glvq cache kinds)", host=host)
        self._m_book_bytes.set(kvcache.codebook_bytes(
            self.cfg, ecfg.cache_kind, ecfg.kv_bits, ecfg.kv_d))

    def _record_iteration(self, t: int, valid_toks: int, live_events:
                          List[TokenEvent], step_s: float, dispatch_s: float):
        """Per-iteration bookkeeping: slab shape / padding counters, KV pool
        gauges, and the JSONL trace record."""
        slab = len(self.slots) * t
        pad = slab - valid_toks
        mx = self._mx
        if mx is not None:
            self._m_step.observe(step_s)
            self._m_dispatch.observe(dispatch_s)
            self._m_valid.inc(valid_toks)
            self._m_pad.inc(pad)
            self._m_pad_frac.set(pad / slab if slab else 0.0)
            w = self._m_width.get(t)
            if w is None:
                w = self._m_width[t] = mx.counter(
                    "serving_iterations_total",
                    "engine iterations by slab width (policy rung)",
                    width=t, policy=self._policy_name)
            w.inc()
            self._m_compile.set_cumulative(self._compiles)
            resident = self._resident_bytes()
            self._m_resident.set(resident)
            live_toks = sum(s.pos for s in self.slots if not s.free)
            self._m_bpt.set(resident / live_toks if live_toks else 0.0)
            if self.pages is not None:
                al = self.pages.alloc
                self._m_blocks_used.set(al.used_blocks)
                self._m_blocks_free.set(al.free_blocks)
                self._m_blocks_hw.set(al.high_water)
                self._m_allocs.set_cumulative(al.total_allocs)
                self._m_frees.set_cumulative(al.total_frees)
                self._m_dfree.set_cumulative(al.double_free_rejected)
                self._m_exhaust.set_cumulative(al.pool_exhausted)
            if self.prefix is not None:
                pc = self.prefix
                self._m_pfx_hits.set_cumulative(pc.hits)
                self._m_pfx_miss.set_cumulative(pc.misses)
                self._m_pfx_tokens.set_cumulative(pc.tokens_reused)
                self._m_pfx_cow.set_cumulative(pc.cow_copies)
                self._m_pfx_evict.set_cumulative(pc.evictions)
                self._m_pfx_resident.set(pc.resident_blocks)
        if self._trace_log is not None:
            rec = dict(kind="iteration", iter=self._iterations, width=t,
                       slots=len(self.slots), valid_tokens=valid_toks,
                       padded_fraction=pad / slab if slab else 0.0,
                       step_s=step_s, dispatch_s=dispatch_s,
                       compiles=self._compiles,
                       events=[dict(rid=e.rid, token=e.token, index=e.index,
                                    done=e.done, done_reason=e.done_reason)
                               for e in live_events])
            if self.pages is not None:
                al = self.pages.alloc
                rec["kv_blocks_used"] = al.used_blocks
                rec["kv_blocks_high_water"] = al.high_water
            self._trace_log.write(rec)

    def _resident_bytes(self) -> int:
        """Modeled resident attention-cache bytes across live slots at their
        current positions (the analytic ``kvcache.cache_bytes`` model — the
        same source of truth the capacity benchmarks use)."""
        ecfg = self.engine_config
        return sum(
            kvcache.cache_bytes(self.cfg, ecfg.cache_kind, s.pos,
                                self.s_cache, ecfg.block_size,
                                self._dtype_bytes, ecfg.kv_bits)
            for s in self.slots if not s.free)

    @property
    def greedy(self) -> bool:
        """Back-compat view of the old flag: are default requests greedy?"""
        return self.default_params.greedy

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        if not req.prompt:
            # the decode branch seeds from the last prompt token; with no
            # prompt there is nothing to condition on and step() would die
            # with an opaque IndexError
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.s_cache:
            # the retire check would otherwise "finish" the request mid-
            # prompt once pos hits s_cache and return garbage tokens
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit the serving cache (s_cache={self.s_cache}); at "
                "least one position must remain for generation — raise "
                "s_cache or truncate the prompt")
        req.t_submit = time.perf_counter()
        if self._mx is not None:
            self._m_submitted.inc()
        self.queue.append(req)

    def pending(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- one engine iteration ------------------------------------------------
    def step(self) -> List[TokenEvent]:
        """One hybrid iteration: the policy picks the slab shape, the
        compiled step advances every live slot and samples their next
        tokens on device.  Returns the TokenEvents this iteration emitted."""
        with trace.host_span("engine_step"):
            return self._step_iteration()

    def _step_iteration(self) -> List[TokenEvent]:
        t_iter = time.perf_counter()
        self._claim(self.policy.assign(self.slots, self.queue))
        remaining = [None if s.free
                     else max(len(s.req.prompt) - s.prompt_cursor, 0)
                     for s in self.slots]
        t, takes = self.policy.widths(remaining, self.chunk)
        # clamp whatever the policy returned: self.chunk already encodes the
        # sliding-window ring bound, and a wider slab would let a chunk's
        # ring writes overwrite keys its own earlier queries still need
        t = max(1, min(int(t), self.chunk))
        b = len(self.slots)
        toks = np.full((b, t), self.pad, np.int32)
        poss = np.zeros((b,), np.int32)
        lens = np.zeros((b,), np.int32)
        seeds = np.zeros((b,), np.int32)
        sidx = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        tks = np.zeros((b,), np.int32)
        tps = np.ones((b,), np.float32)
        for i, s in enumerate(self.slots):
            if s.free:
                continue                      # lens=0: fully masked
            r = s.req
            rem = len(r.prompt) - s.prompt_cursor
            if rem > 0:
                take = min(int(takes[i]), rem, t)
                if take <= 0:
                    continue                  # policy deferred this slot
                toks[i, :take] = r.prompt[s.prompt_cursor:
                                          s.prompt_cursor + take]
            else:
                take = 1
                toks[i, 0] = r.tokens[-1] if r.tokens else r.prompt[-1]
            poss[i] = s.pos
            lens[i] = take
            sp = r.params if r.params is not None else self.default_params
            seeds[i] = (sp.seed if sp.seed is not None else r.rid) \
                & 0x7FFFFFFF
            sidx[i] = len(r.tokens)
            temps[i] = sp.temperature
            tks[i] = sp.top_k
            tps[i] = sp.top_p
            if self.pages is not None:
                # grant every block the chunk will touch up front
                self.pages.ensure(i, s.pos + take - 1)
        if self.pages is not None and self.pages.dirty:
            self.cache["table"] = self.pages.device_table()
        if self._debug and self.pages is not None:
            # catch allocator corruption BEFORE the step consumes the table
            self._debug_guard(
                lambda: debug_runtime.check_block_aliasing(self.pages))
        t_dispatch = time.perf_counter()
        step_args = (
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss),
            jnp.asarray(lens), jnp.asarray(seeds), jnp.asarray(sidx),
            jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(tps))
        if self._debug:
            err, (out, self.cache) = self._checked_step(*step_args)
        else:
            out, self.cache = self._step(*step_args)
        if self.engine_config.sync_timing:
            # honest host-side step latency: wait out the async dispatch
            # before stopping the clock (costs pipelining; off by default)
            jax.block_until_ready(out)
        dispatch_s = time.perf_counter() - t_dispatch
        if self._debug:
            failure = debug_runtime.consume_error(err)   # syncs; debug-only
            if failure is not None:
                self._debug_trip(failure)
        nxt, lps, tvs, tis = (np.asarray(a) for a in out)
        n_top = tvs.shape[1]
        now = time.perf_counter()
        events: List[TokenEvent] = []
        for i, s in enumerate(self.slots):
            if s.free or lens[i] == 0:
                continue
            r = s.req
            take = int(lens[i])
            s.pos += take
            tok = None
            if s.prompt_cursor < len(r.prompt):
                s.prompt_cursor += take
                if s.prompt_cursor == len(r.prompt):
                    tok = int(nxt[i])          # first generated token
                    if self.prefix is not None:
                        # the prompt's full blocks are finalized now —
                        # index them so concurrent same-prefix requests
                        # hit without waiting for this one to retire
                        self._prefix_register(i, s, r)
            else:
                tok = int(nxt[i])
            if tok is None:
                continue                       # still mid-prompt
            r.tokens.append(tok)
            if r.t_first_token is None:
                r.t_first_token = now
                if self._mx is not None and r.t_submit is not None:
                    self._m_ttft.observe(now - r.t_submit)
            elif self._mx is not None and r.t_last_token is not None:
                self._m_itl.observe(now - r.t_last_token)
            r.t_last_token = now
            if self._mx is not None:
                self._m_tokens.inc()
            reason = self._done_reason(r, s, tok)
            if reason is not None:
                r.done = True
                r.done_reason = reason
                self.finished[r.rid] = r
                self.slots[i] = _Slot()        # slot recycled at pos 0
                if self.pages is not None:
                    if self.prefix is not None:
                        # index the generated extension too (multi-turn:
                        # the next turn's prompt embeds this whole reply)
                        self._prefix_register(i, s, r)
                    # one decref per block: exclusive blocks return to the
                    # free list, shared/indexed ones stay resident
                    self.pages.release(i)
                if self._mx is not None:
                    self._mx.counter("serving_requests_finished_total",
                                     "retired requests by done_reason",
                                     reason=reason).inc()
            top = tuple((int(tis[i, k]), float(tvs[i, k]))
                        for k in range(n_top)) if n_top else None
            events.append(TokenEvent(rid=r.rid, token=tok,
                                     index=len(r.tokens) - 1, done=r.done,
                                     done_reason=r.done_reason,
                                     logprob=float(lps[i]),
                                     top_logprobs=top))
        self._iterations += 1
        if self._debug:
            self._debug_guard(lambda: self._recompile_monitor.observe(
                self._compiles, self._iterations))
        if self._mx is not None or self._trace_log is not None:
            self._record_iteration(t, int(np.sum(lens)), events,
                                   time.perf_counter() - t_iter, dispatch_s)
        return events

    # -- debug_checks plumbing (repro.analysis.runtime) -----------------------
    def _debug_guard(self, check_fn):
        """Run a host-side sanitizer check, routing trips through
        ``_debug_trip`` so every failure is counted before it raises."""
        try:
            check_fn()
        except debug_runtime.DebugCheckError as e:
            self._debug_trip(e)

    def _debug_trip(self, e: "debug_runtime.DebugCheckError"):
        """Count the trip on the Prometheus surface, then raise: sanitizer
        failures must be visible in dashboards even when the exception is
        swallowed by a driver's retry loop."""
        self.metrics.counter(
            debug_runtime.FAILURE_COUNTER,
            "runtime sanitizer trips by check (EngineConfig.debug_checks)",
            check=e.check).inc()
        raise e

    def _done_reason(self, r: Request, s: _Slot, tok: int) -> Optional[str]:
        sp = r.params if r.params is not None else self.default_params
        if tok in sp.stop_token_ids or tok in self.engine_config.stop_tokens:
            return DONE_STOP
        limit = sp.max_tokens if sp.max_tokens is not None else r.max_new
        if len(r.tokens) >= limit:
            return DONE_LENGTH
        if s.pos >= self.s_cache:
            return DONE_CACHE_FULL
        return None

    def _claim(self, assignments):
        now = time.perf_counter()
        for i, req in assignments:
            if req.t_first_sched is None:
                req.t_first_sched = now
                if self._mx is not None and req.t_submit is not None:
                    self._m_queue_wait.observe(now - req.t_submit)
            self.slots[i] = _Slot(req=req, pos=0, prompt_cursor=0)
            if self._recurrent:
                # a retired request's conv window / hidden state must not
                # leak into the new occupant
                self.cache = self._reset(self.cache,
                                         jnp.asarray(i, jnp.int32))
            if self.prefix is not None:
                self._prefix_claim(i, req)

    def _prefix_claim(self, i: int, req: Request):
        """Map a freshly-claimed slot's prompt onto cached blocks: full
        matches are aliased read-only (incref), a partial boundary match is
        copy-on-write copied into a private block, and the slot starts its
        prefill at the divergence offset."""
        pc = self.prefix
        bs = self.pages.layout.block_size
        chain, matched = pc.match(req.prompt)
        # at least one prompt token must still run through the model so the
        # chunk step has logits to sample the first output token from
        usable = min(matched, len(req.prompt) - 1)
        n_full = usable // bs
        if n_full < pc.min_blocks:
            pc.misses += 1
            return
        boundary = usable - n_full * bs        # tokens into block n_full
        self.pages.adopt(i, chain[:n_full])
        cached = n_full * bs
        if boundary:
            src = int(chain[n_full])
            pc.alloc.incref(src)               # pin against eviction
            try:
                self.pages.ensure(i, cached)   # one private block at n_full
                dst = int(self.pages.table[i, n_full])
                self.cache = self._copy_block(self.cache, src, dst)
                pc.cow_copies += 1
                cached += boundary
            except RuntimeError:
                # pool too tight to grant the CoW copy's block — keep the
                # full-block hit and recompute the boundary tokens
                pass
            finally:
                pc.alloc.decref(src)           # re-parks via retain hook
        s = self.slots[i]
        s.pos = cached
        s.prompt_cursor = cached               # budget sees only the rest
        pc.hits += 1
        pc.tokens_reused += cached

    def _prefix_register(self, i: int, s: _Slot, r: Request):
        """Index slot ``i``'s finalized FULL blocks (every position below
        ``s.pos`` is written) so later requests can alias them."""
        bs = self.pages.layout.block_size
        n_full = min(s.pos // bs, int(self.pages.counts[i]))
        if n_full < 1:
            return
        seq = (r.prompt + r.tokens)[:n_full * bs]
        blocks = [int(b) for b in self.pages.table[i, :n_full]]
        self.prefix.insert(seq, blocks)
