"""Continuous-batching serving scheduler.

Decode-only continuous batching (Orca-style): a fixed number of batch slots
advance one token per model step; finished requests retire and queued requests
claim slots immediately — prompts are prefilled token-by-token through the
same decode step, so a single compiled program serves the whole lifecycle
(no prefill/decode program switch, no recompilation as load changes).

Idle slots feed a pad token at their stale position; this is safe for
attention caches because a newly-assigned slot restarts at position 0 and the
causal validity mask hides anything beyond the current position.  Recurrent
families (mamba2 / rglru / hybrid) integrate state every step, so the
scheduler zeroes a slot's recurrent state when a new request claims it
(``registry.reset_slot``) — slot churn cannot leak one request's state into
the next.

Cache modes (``cache_kind``): ``dense`` keeps per-slot max-length K/V
buffers; ``paged`` / ``paged_q8`` / ``paged_q8c`` switch every attention
layer to shared block pools (``serving.kvcache``) — the scheduler grants a
slot one block at a time as its position crosses block boundaries and
returns all of the slot's blocks to the free list when the request retires,
so resident cache bytes track live tokens instead of worst-case length.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import kvcache

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    prompt_cursor: int = 0      # how many prompt tokens already consumed

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 s_cache: int = 64, dtype=jnp.float32, qmeta=None,
                 backend: Optional[str] = None, pad_token: int = 0,
                 greedy: bool = True, cache_kind: str = "dense",
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 kv_backend: Optional[str] = None, mesh=None):
        """``qmeta`` + ``backend`` route every weight matmul in the compiled
        decode step through the quantized-execution engine (QuantTensor
        dispatch); ``cache_kind`` + ``kv_backend`` route the attention cache
        through the paged KV engine (``kernels.kv_cache``); ``None`` backends
        use the platform default.  ``mesh`` runs quantized matmuls tensor-
        parallel (shard_map over the mesh's "model" axis) — works with every
        ``cache_kind``."""
        if cache_kind not in kvcache.CACHE_KINDS:
            raise ValueError(f"unknown cache_kind {cache_kind!r}; "
                             f"available: {kvcache.CACHE_KINDS}")
        self.params = params
        self.cfg = cfg
        self.s_cache = s_cache
        self.pad = pad_token
        self.greedy = greedy
        self.cache_kind = cache_kind
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self.pages: Optional[kvcache.SlotPages] = None
        if cache_kind != "dense":
            layout = kvcache.PageLayout.plan(s_cache, slots, block_size,
                                             num_blocks)
            self.pages = kvcache.SlotPages(slots, layout)
            num_blocks = layout.num_blocks
        self.cache = registry.cache_init(cfg, slots, s_cache, dtype,
                                         cache_kind=cache_kind,
                                         block_size=block_size,
                                         num_blocks=num_blocks)
        self._recurrent = registry.has_recurrent(cfg)
        self._reset = jax.jit(
            lambda c, i: registry.reset_slot(c, cfg, i))
        self._step = jax.jit(lambda p, c, t, pos: registry.decode_step(
            p, c, t, pos, cfg, dtype=dtype, qmeta=qmeta, backend=backend,
            cache_kind=cache_kind, kv_backend=kv_backend, s_cache=s_cache,
            mesh=mesh))

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def pending(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- one engine iteration --------------------------------------------------
    def step(self):
        self._assign_slots()
        toks, poss = [], []
        for i, s in enumerate(self.slots):
            if s.free:
                toks.append(self.pad)
                poss.append(max(s.pos - 1, 0))
                continue
            if self.pages is not None:
                self.pages.ensure(i, s.pos)   # grant the block pos lands in
            r = s.req
            if s.prompt_cursor < len(r.prompt):
                toks.append(r.prompt[s.prompt_cursor])
            else:
                toks.append(r.tokens[-1] if r.tokens else r.prompt[-1])
            poss.append(s.pos)
        if self.pages is not None and self.pages.dirty:
            self.cache["table"] = self.pages.device_table()
        logits, self.cache = self._step(
            self.params, self.cache,
            jnp.asarray(toks, jnp.int32), jnp.asarray(poss, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, -1)) if self.greedy else None
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            r = s.req
            s.pos += 1
            if s.prompt_cursor < len(r.prompt):
                s.prompt_cursor += 1
                if s.prompt_cursor == len(r.prompt):
                    r.tokens.append(int(nxt[i]))   # first generated token
            else:
                r.tokens.append(int(nxt[i]))
            if len(r.tokens) >= r.max_new or s.pos >= self.s_cache:
                r.done = True
                self.finished[r.rid] = r
                self.slots[i] = _Slot()            # slot recycled at pos 0
                if self.pages is not None:
                    self.pages.release(i)          # blocks back to the pool

    def _assign_slots(self):
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                req = self.queue.popleft()
                self.slots[i] = _Slot(req=req, pos=0, prompt_cursor=0)
                if self._recurrent:
                    # a retired request's conv window / hidden state must not
                    # leak into the new occupant
                    self.cache = self._reset(self.cache,
                                             jnp.asarray(i, jnp.int32))
