"""Continuous-batching serving scheduler.

Decode-only continuous batching (Orca-style): a fixed number of batch slots
advance one token per model step; finished requests retire and queued requests
claim slots immediately — prompts are prefilled token-by-token through the
same decode step, so a single compiled program serves the whole lifecycle
(no prefill/decode program switch, no recompilation as load changes).

Idle slots feed a pad token at their stale position; this is safe for
attention caches because a newly-assigned slot restarts at position 0 and the
causal validity mask hides anything beyond the current position. (Recurrent
caches — mamba2 / rglru — would need per-slot state resets; the scheduler
checks the family and refuses, documented limitation.)
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    prompt_cursor: int = 0      # how many prompt tokens already consumed

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 s_cache: int = 64, dtype=jnp.float32, qmeta=None,
                 backend: Optional[str] = None, pad_token: int = 0,
                 greedy: bool = True):
        """``qmeta`` + ``backend`` route every weight matmul in the compiled
        decode step through the quantized-execution engine (QuantTensor
        dispatch); ``backend=None`` uses the platform default."""
        if cfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                "continuous batching needs per-slot recurrent-state resets "
                "for ssm/hybrid families")
        self.params = params
        self.cfg = cfg
        self.s_cache = s_cache
        self.pad = pad_token
        self.greedy = greedy
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self.cache = registry.cache_init(cfg, slots, s_cache, dtype)
        self._step = jax.jit(lambda p, c, t, pos: registry.decode_step(
            p, c, t, pos, cfg, dtype=dtype, qmeta=qmeta, backend=backend))

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def pending(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- one engine iteration --------------------------------------------------
    def step(self):
        self._assign_slots()
        toks, poss = [], []
        for s in self.slots:
            if s.free:
                toks.append(self.pad)
                poss.append(max(s.pos - 1, 0))
                continue
            r = s.req
            if s.prompt_cursor < len(r.prompt):
                toks.append(r.prompt[s.prompt_cursor])
            else:
                toks.append(r.tokens[-1] if r.tokens else r.prompt[-1])
            poss.append(s.pos)
        logits, self.cache = self._step(
            self.params, self.cache,
            jnp.asarray(toks, jnp.int32), jnp.asarray(poss, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, -1)) if self.greedy else None
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            r = s.req
            s.pos += 1
            if s.prompt_cursor < len(r.prompt):
                s.prompt_cursor += 1
                if s.prompt_cursor == len(r.prompt):
                    r.tokens.append(int(nxt[i]))   # first generated token
            else:
                r.tokens.append(int(nxt[i]))
            if len(r.tokens) >= r.max_new or s.pos >= self.s_cache:
                r.done = True
                self.finished[r.rid] = r
                self.slots[i] = _Slot()            # slot recycled at pos 0

    def _assign_slots(self):
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                req = self.queue.popleft()
                self.slots[i] = _Slot(req=req, pos=0, prompt_cursor=0)
