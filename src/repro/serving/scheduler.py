"""Continuous-batching serving scheduler with chunked prefill.

Hybrid (Sarathi-style) continuous batching: a fixed number of batch slots
advance through ONE variable-width engine step (``registry.chunk_step``) per
iteration.  Decode slots consume exactly one token; prefill slots consume up
to ``chunk_size`` prompt tokens, so time-to-first-token scales with
``len(prompt) / chunk_size`` instead of ``len(prompt)`` and the backbone's
quantized matmuls run at M = B*T where the fused GLVQ kernels pay off.  Both
widths are the SAME code path — the engine compiles exactly two program
shapes (T = chunk_size while any prompt is in flight, T = 1 for steady-state
decode), so there is no prefill/decode program switch and no recompilation
as load changes.

Idle slots carry ``lens = 0``: every KV write, recurrent-state update, and
logit of their pad positions is masked inside the chunk step.  Recurrent
families (mamba2 / rglru / hybrid) integrate state every step, so the
scheduler zeroes a slot's recurrent state when a new request claims it
(``registry.reset_slot``) — slot churn cannot leak one request's state into
the next.

Cache modes (``cache_kind``): ``dense`` keeps per-slot max-length K/V
buffers; ``paged`` / ``paged_q8`` / ``paged_q8c`` switch every attention
layer to shared block pools (``serving.kvcache``) — the scheduler grants a
slot ALL the blocks its chunk will touch up front (whole blocks land per
step via the batched append kernel) and returns them to the free list when
the request retires, so resident cache bytes track live tokens instead of
worst-case length.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import kvcache

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    prompt_cursor: int = 0      # how many prompt tokens already consumed

    @property
    def free(self) -> bool:
        return self.req is None


def _local_ring(cfg: ModelConfig, s_cache: int) -> Optional[int]:
    """Smallest sliding-window ring length in the stack, if any."""
    kinds = tuple(cfg.scan_unit) + tuple(cfg.scan_tail)
    if cfg.window and any(k == "attn_local" for k in kinds):
        return min(cfg.window, s_cache)
    return None


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 s_cache: int = 64, dtype=jnp.float32, qmeta=None,
                 backend: Optional[str] = None, pad_token: int = 0,
                 greedy: bool = True, cache_kind: str = "dense",
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 kv_backend: Optional[str] = None, mesh=None,
                 chunk_size: int = 1):
        """``qmeta`` + ``backend`` route every weight matmul in the compiled
        serving step through the quantized-execution engine (QuantTensor
        dispatch); ``cache_kind`` + ``kv_backend`` route the attention cache
        through the paged KV engine (``kernels.kv_cache``); ``None`` backends
        use the platform default.  ``mesh`` runs quantized matmuls tensor-
        parallel (shard_map over the mesh's "model" axis) — works with every
        ``cache_kind``.  ``chunk_size`` > 1 enables chunked prefill: a
        prefill slot consumes up to that many prompt tokens per engine
        iteration (clamped to the smallest sliding-window ring so local
        attention layers never overwrite keys the chunk still has to read);
        ``chunk_size=1`` is the token-by-token baseline."""
        if cache_kind not in kvcache.CACHE_KINDS:
            raise ValueError(f"unknown cache_kind {cache_kind!r}; "
                             f"available: {kvcache.CACHE_KINDS}")
        self.params = params
        self.cfg = cfg
        self.s_cache = s_cache
        self.pad = pad_token
        self.greedy = greedy
        self.cache_kind = cache_kind
        chunk = max(1, int(chunk_size))
        ring = _local_ring(cfg, s_cache)
        if ring is not None:
            chunk = min(chunk, ring)
        self.chunk = min(chunk, s_cache)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self.pages: Optional[kvcache.SlotPages] = None
        if cache_kind != "dense":
            layout = kvcache.PageLayout.plan(s_cache, slots, block_size,
                                             num_blocks)
            self.pages = kvcache.SlotPages(slots, layout)
            num_blocks = layout.num_blocks
        self.cache = registry.cache_init(cfg, slots, s_cache, dtype,
                                         cache_kind=cache_kind,
                                         block_size=block_size,
                                         num_blocks=num_blocks)
        self._recurrent = registry.has_recurrent(cfg)
        self._reset = jax.jit(
            lambda c, i: registry.reset_slot(c, cfg, i))
        # ONE jitted program family: T=1 (steady decode) and T=chunk
        # (prefill in flight) are the only shapes it ever sees
        self._step = jax.jit(lambda p, c, t, pos, lens: registry.chunk_step(
            p, c, t, pos, lens, cfg, dtype=dtype, qmeta=qmeta,
            backend=backend, cache_kind=cache_kind, kv_backend=kv_backend,
            s_cache=s_cache, mesh=mesh))

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) >= self.s_cache:
            # the retire check would otherwise "finish" the request mid-
            # prompt once pos hits s_cache and return garbage tokens
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit the serving cache (s_cache={self.s_cache}); at "
                "least one position must remain for generation — raise "
                "s_cache or truncate the prompt")
        self.queue.append(req)

    def pending(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- one engine iteration ------------------------------------------------
    def step(self):
        """One hybrid iteration: decode slots (1 token) and prefill slots
        (up to ``chunk_size`` prompt tokens) pack into one token slab."""
        self._assign_slots()
        prefilling = any(
            not s.free and s.prompt_cursor < len(s.req.prompt)
            for s in self.slots)
        t = self.chunk if (prefilling and self.chunk > 1) else 1
        toks = np.full((len(self.slots), t), self.pad, np.int32)
        poss = np.zeros((len(self.slots),), np.int32)
        lens = np.zeros((len(self.slots),), np.int32)
        for i, s in enumerate(self.slots):
            if s.free:
                continue                      # lens=0: fully masked
            r = s.req
            remaining = len(r.prompt) - s.prompt_cursor
            if remaining > 0:
                take = min(remaining, t)
                toks[i, :take] = r.prompt[s.prompt_cursor:
                                          s.prompt_cursor + take]
            else:
                take = 1
                toks[i, 0] = r.tokens[-1] if r.tokens else r.prompt[-1]
            poss[i] = s.pos
            lens[i] = take
            if self.pages is not None:
                # grant every block the chunk will touch up front
                self.pages.ensure(i, s.pos + take - 1)
        if self.pages is not None and self.pages.dirty:
            self.cache["table"] = self.pages.device_table()
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss),
            jnp.asarray(lens))
        nxt = np.asarray(jnp.argmax(logits, -1)) if self.greedy else None
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            r = s.req
            take = int(lens[i])
            s.pos += take
            if s.prompt_cursor < len(r.prompt):
                s.prompt_cursor += take
                if s.prompt_cursor == len(r.prompt):
                    r.tokens.append(int(nxt[i]))   # first generated token
            else:
                r.tokens.append(int(nxt[i]))
            if len(r.tokens) >= r.max_new or s.pos >= self.s_cache:
                r.done = True
                self.finished[r.rid] = r
                self.slots[i] = _Slot()            # slot recycled at pos 0
                if self.pages is not None:
                    self.pages.release(i)          # blocks back to the pool

    def _assign_slots(self):
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                req = self.queue.popleft()
                self.slots[i] = _Slot(req=req, pos=0, prompt_cursor=0)
                if self._recurrent:
                    # a retired request's conv window / hidden state must not
                    # leak into the new occupant
                    self.cache = self._reset(self.cache,
                                             jnp.asarray(i, jnp.int32))
