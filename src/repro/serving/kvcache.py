"""Paged, quantized KV-cache bookkeeping: block-pool allocator + block tables.

HBM layout (device side, built by ``models.lm.cache_init``):
  * every attention layer owns pools ``[num_blocks, block_size, KV, hd]``
    (+ per-token f16 scales for the quantized modes — see
    ``kernels.kv_cache``);
  * one block table ``int32 [slots, blocks_per_slot]`` is shared by all
    layers and lives at the top of the cache pytree (``cache["table"]``);
  * block 0 is a reserved scratch block: idle slots' pad-token writes land
    there and it is never handed out by the allocator, so stale scratch
    content can never alias a live slot's history.

Host side (this module): ``BlockAllocator`` is a plain free-list over block
ids 1..num_blocks-1; ``SlotPages`` tracks which table entries each slot has
been granted, allocating lazily as a slot's position crosses a block boundary
and returning all of a slot's blocks to the free list when it retires.  Local
(sliding-window) attention layers write ring-style at ``pos % window`` and so
only ever touch a slot's first ``ceil(window / block_size)`` table entries —
the shared table needs no per-layer variants.

Byte accounting helpers at the bottom are the analytic source of truth for
``benchmarks/kvcache.py`` (bytes/token, max resident slots at a fixed HBM
budget).
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.kv_cache import MODES, PageLayout

__all__ = ["CACHE_KINDS", "PageLayout", "BlockAllocator", "SlotPages",
           "static_table", "attn_layer_lengths", "cache_bytes",
           "bytes_per_token", "max_resident_slots"]

# every kernel-level paged mode plus the dense oracle — derived so the two
# lists cannot drift
CACHE_KINDS = ("dense",) + MODES

_ATTN_KINDS = ("attn", "attn_local", "attn_moe")


class BlockAllocator:
    """Free-list allocator over pool block ids; id 0 is reserved scratch.

    Beyond the free list it keeps the telemetry the serving metrics read
    each iteration: ``high_water`` (max blocks ever live at once — the
    capacity-planning number), cumulative ``total_allocs`` / ``total_frees``,
    ``pool_exhausted`` (failed allocs), and ``double_free_rejected`` (the
    PR-3 guard fired — counted *and* raised, so a crash-looping caller is
    visible in the metrics, not just in its own traceback)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        self._free_set: set[int] = set(self._free)
        self._ever_used: set[int] = set()
        self.recycled = 0                       # re-allocations of freed blocks
        self.high_water = 0                     # max used_blocks ever seen
        self.total_allocs = 0
        self.total_frees = 0
        self.pool_exhausted = 0                 # allocs that failed
        self.double_free_rejected = 0           # frees the guard refused

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            self.pool_exhausted += 1
            raise RuntimeError(
                "KV block pool exhausted: all "
                f"{self.num_blocks - 1} blocks are live. Retire requests, "
                "raise num_blocks, or admit fewer concurrent slots.")
        bid = self._free.popleft()
        self._free_set.discard(bid)
        if bid in self._ever_used:
            self.recycled += 1
        self._ever_used.add(bid)
        self.total_allocs += 1
        if self.used_blocks > self.high_water:
            self.high_water = self.used_blocks
        return bid

    def free(self, ids: Iterable[int]):
        """Return blocks to the pool.  A double-free is an error, not a
        shrug: re-listing a block would hand it to two live slots and corrupt
        cross-request KV history the next time either one writes.

        Validates the whole batch before mutating anything, so a raise never
        leaves the pool half-released."""
        add = []
        for bid in ids:
            bid = int(bid)
            if not bid:                         # never recycle scratch 0
                continue
            if bid < 0 or bid >= self.num_blocks:
                raise ValueError(
                    f"free of out-of-range KV block id {bid} "
                    f"(pool has blocks 1..{self.num_blocks - 1})")
            if bid in self._free_set or bid in add:
                # also catches freeing a block that was never handed out:
                # every non-live block sits on the free list by invariant
                self.double_free_rejected += 1
                raise RuntimeError(
                    f"double free of KV block {bid}: it is already on the "
                    "free list; freeing it again would alias two slots onto "
                    "one block")
            add.append(bid)
        self._free.extend(add)
        self._free_set.update(add)
        self.total_frees += len(add)


class SlotPages:
    """Per-slot block-table bookkeeping for the continuous-batching scheduler.

    The host table mirrors ``cache["table"]`` on device; ``dirty`` marks when
    the device copy must be refreshed before the next decode step.
    """

    def __init__(self, slots: int, layout: PageLayout):
        self.layout = layout
        self.alloc = BlockAllocator(layout.num_blocks)
        self.table = np.zeros((slots, layout.blocks_per_slot), np.int32)
        self.counts = np.zeros((slots,), np.int32)   # granted entries per slot
        self.dirty = True                            # device table unset yet

    def ensure(self, slot: int, pos: int):
        """Grant slot all table entries needed to write position ``pos``."""
        need = pos // self.layout.block_size + 1
        while self.counts[slot] < need:
            self.table[slot, self.counts[slot]] = self.alloc.alloc()
            self.counts[slot] += 1
            self.dirty = True

    def release(self, slot: int):
        """Return a retired slot's blocks; its row falls back to scratch 0."""
        n = int(self.counts[slot])
        if n:
            self.alloc.free(self.table[slot, :n].tolist())
            self.table[slot, :n] = 0
            self.counts[slot] = 0
            self.dirty = True

    def device_table(self) -> jnp.ndarray:
        self.dirty = False
        return jnp.asarray(self.table)


def static_table(batch: int, blocks_per_slot: int) -> jnp.ndarray:
    """Fully-preallocated contiguous table (row b owns blocks
    [1 + b*bps, 1 + (b+1)*bps)) — for plain batched decode loops that don't
    run an allocator (``launch.serve`` demo, benchmarks)."""
    base = 1 + blocks_per_slot * np.arange(batch)[:, None]
    return jnp.asarray(base + np.arange(blocks_per_slot)[None], jnp.int32)


# ---------------------------------------------------------------------------
# Analytic byte accounting (benchmarks + capacity planning)
# ---------------------------------------------------------------------------

def _attn_layers(cfg: ModelConfig, s_cache: int) -> List[tuple]:
    """Per attention layer: (retained positions, is_sliding_window)."""
    out = []
    kinds = list(cfg.scan_unit) * cfg.n_repeats + list(cfg.scan_tail)
    for kind in kinds:
        if kind in _ATTN_KINDS:
            if kind == "attn_local" and cfg.window:
                out.append((min(cfg.window, s_cache), True))
            else:
                out.append((s_cache, False))
    return out


def attn_layer_lengths(cfg: ModelConfig, s_cache: int) -> List[int]:
    """Per attention layer: how many cache positions it retains (global
    layers keep s_cache; sliding-window layers keep min(window, s_cache))."""
    return [s for s, _ in _attn_layers(cfg, s_cache)]


def _per_pos_bytes(cfg: ModelConfig, kind: str, dtype_bytes: int) -> float:
    """K+V bytes for one retained position of one attention layer."""
    per_head = cfg.n_kv_heads * cfg.hd
    if kind in ("dense", "paged"):
        return 2 * per_head * dtype_bytes
    # int8 codes + f16 per-token-per-head scale
    return 2 * (per_head * 1 + cfg.n_kv_heads * 2)


def cache_bytes(cfg: ModelConfig, kind: str, seq_len: int, s_cache: int,
                block_size: int = 16, dtype_bytes: int = 2) -> int:
    """Resident attention-cache bytes for ONE slot holding ``seq_len`` tokens.

    Dense reserves every layer's full retained length up front.  Paged
    GLOBAL layers only hold the blocks the sequence has actually touched
    (lazy allocator grants); paged SLIDING-WINDOW layers statically own
    their whole ring — ``ceil(min(window, s_cache) / block_size)`` blocks
    per slot in a layer-private pool from init (``models.layers.
    paged_attn_cache_init``) — so their bytes never scale with seq_len."""
    if kind not in CACHE_KINDS:
        raise ValueError(f"unknown cache kind {kind!r}; "
                         f"available: {CACHE_KINDS}")
    total = 0.0
    for s_layer, local in _attn_layers(cfg, s_cache):
        if kind == "dense":
            total += s_layer * _per_pos_bytes(cfg, kind, dtype_bytes)
        else:
            if local:
                blocks = -(-s_layer // block_size)     # static ring ownership
            else:
                touched = min(seq_len, s_layer)
                blocks = -(-touched // block_size) if touched else 0
            total += blocks * block_size * _per_pos_bytes(cfg, kind,
                                                          dtype_bytes)
    if kind != "dense":
        total += 4 * (-(-s_cache // block_size))      # int32 table row
    return int(total)


def bytes_per_token(cfg: ModelConfig, kind: str, seq_len: int, s_cache: int,
                    block_size: int = 16, dtype_bytes: int = 2) -> float:
    """Resident cache bytes per stored token at sequence length ``seq_len``."""
    return cache_bytes(cfg, kind, seq_len, s_cache, block_size,
                       dtype_bytes) / max(seq_len, 1)


def max_resident_slots(cfg: ModelConfig, kind: str, hbm_bytes: float,
                       seq_len: int, s_cache: int, block_size: int = 16,
                       dtype_bytes: int = 2) -> int:
    """How many concurrent slots at ``seq_len`` fit a fixed cache budget."""
    per_slot = cache_bytes(cfg, kind, seq_len, s_cache, block_size,
                           dtype_bytes)
    return int(hbm_bytes // max(per_slot, 1))
