"""Paged, quantized KV-cache bookkeeping: block-pool allocator + block tables.

HBM layout (device side, built by ``models.lm.cache_init``):
  * every attention layer owns pools ``[num_blocks, block_size, KV, hd]``
    (+ per-token f16 scales for the quantized modes — see
    ``kernels.kv_cache``);
  * one block table ``int32 [slots, blocks_per_slot]`` is shared by all
    layers and lives at the top of the cache pytree (``cache["table"]``);
  * block 0 is a reserved scratch block: idle slots' pad-token writes land
    there and it is never handed out by the allocator, so stale scratch
    content can never alias a live slot's history.

Host side (this module): ``BlockAllocator`` is a REFCOUNTED free-list over
block ids 1..num_blocks-1 — a block is ``free`` (on the free list), ``live``
(refcount >= 1: that many slot tables reference it), or ``cached``
(refcount 0 but retained resident for the prefix cache, evictable under pool
pressure).  ``SlotPages`` tracks which table entries each slot has been
granted, allocating lazily as a slot's position crosses a block boundary and
decref'ing all of a slot's blocks when it retires.  Local (sliding-window)
attention layers write ring-style at ``pos % window`` and so only ever touch
a slot's first ``ceil(window / block_size)`` table entries — the shared
table needs no per-layer variants.

``PrefixCache`` is the radix index over those blocks: one node per FULL
block of tokens, keyed by that block's token tuple, child-of its prefix.  A
new request walks the radix with its prompt; matched full blocks are aliased
read-only into its table (incref), a partially-matched boundary block is
copied (copy-on-write — ``copy_block``) so mid-block divergence never
writes into shared history, and the request prefills only from the
divergence point.  Retiring requests register their full blocks back into
the radix; blocks whose refcount hits 0 while registered stay resident as
evictable LRU leaves instead of returning to the free list, so a hot system
prompt survives request churn — and eviction under pool pressure means the
cache never reduces effective capacity.

Byte accounting helpers at the bottom are the analytic source of truth for
``benchmarks/kvcache.py`` (bytes/token, max resident slots at a fixed HBM
budget).
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.kernels.kv_cache import (MODES, PageLayout, copy_pool_block,
                                    default_glvq_spec)

__all__ = ["CACHE_KINDS", "PageLayout", "BlockAllocator", "SlotPages",
           "PrefixCache", "copy_block", "static_table",
           "attn_layer_lengths", "cache_bytes", "bytes_per_token",
           "codebook_bytes", "max_resident_slots"]

# every kernel-level paged mode plus the dense oracle — derived so the two
# lists cannot drift
CACHE_KINDS = ("dense",) + MODES

_ATTN_KINDS = ("attn", "attn_local", "attn_moe")


class BlockAllocator:
    """Refcounted allocator over pool block ids; id 0 is reserved scratch.

    Ownership model (relaxed from PR 3's exclusive grant/free for prefix
    sharing): every resident block carries a refcount — the number of slot
    tables referencing it.  ``alloc`` mints a block at refcount 1,
    ``incref`` aliases it into another slot (read-only sharing), ``decref``
    releases one owner.  When the count hits 0 the block either returns to
    the free list or — when the ``retain`` hook claims it (the prefix cache
    holds a radix node for it) — parks as a refcount-0 CACHED block:
    resident, not allocatable, evictable.  ``alloc`` under pool pressure
    asks the ``reclaim`` hook to evict parked blocks before giving up.

    Beyond that it keeps the telemetry the serving metrics read each
    iteration: ``high_water`` (max blocks ever resident at once — the
    capacity-planning number), cumulative ``total_allocs`` /
    ``total_frees``, ``pool_exhausted`` (failed allocs), and
    ``double_free_rejected`` (a release below refcount 0 — the PR-3
    double-free guard, now enforced through decref; counted *and* raised,
    so a crash-looping caller is visible in the metrics, not just in its
    own traceback)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        self._free_set: set[int] = set(self._free)
        self._refs: Dict[int, int] = {}         # live block -> owner count
        self._parked: set[int] = set()          # refcount-0 cached blocks
        self._ever_used: set[int] = set()
        # hooks bound by PrefixCache: retain(bid) -> bool keeps a refcount-0
        # block resident; reclaim(n) evicts parked blocks under pressure
        self.retain: Optional[Callable[[int], bool]] = None
        self.reclaim: Optional[Callable[[int], int]] = None
        self.recycled = 0                       # re-allocations of freed blocks
        self.high_water = 0                     # max used_blocks ever seen
        self.total_allocs = 0
        self.total_frees = 0
        self.pool_exhausted = 0                 # allocs that failed
        self.double_free_rejected = 0           # releases the guard refused

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Resident blocks: live (refcount >= 1) plus parked (cached)."""
        return (self.num_blocks - 1) - len(self._free)

    @property
    def live_blocks(self) -> int:
        return len(self._refs)

    @property
    def parked_blocks(self) -> int:
        return len(self._parked)

    def refcount(self, bid: int) -> int:
        """Slot-owner count of a block (0 for parked/free blocks)."""
        return self._refs.get(int(bid), 0)

    def alloc(self) -> int:
        if not self._free and self._parked and self.reclaim is not None:
            # pool pressure: ask the prefix cache to evict LRU refcount-0
            # blocks — cached prefixes never reduce effective capacity
            self.reclaim(1)
        if not self._free:
            self.pool_exhausted += 1
            raise RuntimeError(
                "KV block pool exhausted: all "
                f"{self.num_blocks - 1} blocks are live. Retire requests, "
                "raise num_blocks, or admit fewer concurrent slots.")
        bid = self._free.popleft()
        self._free_set.discard(bid)
        self._refs[bid] = 1
        if bid in self._ever_used:
            self.recycled += 1
        self._ever_used.add(bid)
        self.total_allocs += 1
        if self.used_blocks > self.high_water:
            self.high_water = self.used_blocks
        return bid

    def incref(self, bid: int) -> int:
        """Add one owner to a resident block (aliasing a shared prefix block
        into another slot's table).  A parked (refcount-0 cached) block is
        resurrected to live.  Incref of a free / out-of-range block raises:
        its content is not valid history."""
        bid = int(bid)
        if bid in self._refs:
            self._refs[bid] += 1
        elif bid in self._parked:
            self._parked.discard(bid)
            self._refs[bid] = 1
        else:
            raise RuntimeError(
                f"incref of non-resident KV block {bid}: only live or "
                "cached blocks hold valid history that can be shared")
        return bid

    def decref(self, bid: int) -> bool:
        """Release one owner; returns True when the block left the live
        set (refcount hit 0).  Where it goes then depends on the ``retain``
        hook: parked (prefix-cache resident) or back on the free list.
        Releasing a block with no owners is the double-free/below-zero
        error — it would alias two slots onto one block."""
        bid = int(bid)
        if not bid:                              # never recycle scratch 0
            return False
        if bid < 0 or bid >= self.num_blocks:
            raise ValueError(
                f"free of out-of-range KV block id {bid} "
                f"(pool has blocks 1..{self.num_blocks - 1})")
        n = self._refs.get(bid)
        if n is None:
            # parked or free: either way owner count is already 0
            self.double_free_rejected += 1
            raise RuntimeError(
                f"double free of KV block {bid}: its refcount is already 0 "
                "(releasing below zero would alias two slots onto one "
                "block)")
        if n > 1:
            self._refs[bid] = n - 1
            return False
        del self._refs[bid]
        if self.retain is not None and self.retain(bid):
            self._parked.add(bid)                # cached: resident, evictable
        else:
            self._free.append(bid)
            self._free_set.add(bid)
            self.total_frees += 1
        return True

    def release_parked(self, bid: int):
        """Eviction path: a parked (refcount-0 cached) block returns to the
        free list.  Only the prefix cache calls this, after unregistering
        the block's radix node."""
        bid = int(bid)
        if bid not in self._parked:
            raise RuntimeError(
                f"release_parked of KV block {bid} which is not parked "
                f"(refcount {self._refs.get(bid, 0)})")
        self._parked.discard(bid)
        self._free.append(bid)
        self._free_set.add(bid)
        self.total_frees += 1

    def free(self, ids: Iterable[int]):
        """Release one owner from each block (the batch spelling of
        ``decref`` — slot retirement routes a whole table row through it).

        Validates the whole batch before mutating anything, so a raise never
        leaves the pool half-released: every id must be live, and a block
        may appear at most once per batch (one table row references a block
        at most once)."""
        batch = []
        for bid in ids:
            bid = int(bid)
            if not bid:                         # never recycle scratch 0
                continue
            if bid < 0 or bid >= self.num_blocks:
                raise ValueError(
                    f"free of out-of-range KV block id {bid} "
                    f"(pool has blocks 1..{self.num_blocks - 1})")
            if bid not in self._refs or bid in batch:
                # not live (free or parked -> owner count already 0), or
                # listed twice in one batch: releasing below zero
                self.double_free_rejected += 1
                raise RuntimeError(
                    f"double free of KV block {bid}: its refcount is "
                    "already 0 (releasing below zero would alias two slots "
                    "onto one block)")
            batch.append(bid)
        for bid in batch:
            self.decref(bid)


class SlotPages:
    """Per-slot block-table bookkeeping for the continuous-batching scheduler.

    The host table mirrors ``cache["table"]`` on device; ``dirty`` marks when
    the device copy must be refreshed before the next decode step.  With the
    prefix cache on, a slot's leading table entries may ALIAS blocks other
    slots (or the radix index) also reference — the allocator refcounts keep
    the books; aliased blocks are read-only by construction (a slot only
    ever writes positions >= its claim-time ``pos``, which lies past every
    shared block).
    """

    def __init__(self, slots: int, layout: PageLayout):
        self.layout = layout
        self.alloc = BlockAllocator(layout.num_blocks)
        self.table = np.zeros((slots, layout.blocks_per_slot), np.int32)
        self.counts = np.zeros((slots,), np.int32)   # granted entries per slot
        self.dirty = True                            # device table unset yet

    def ensure(self, slot: int, pos: int):
        """Grant slot all table entries needed to write position ``pos``."""
        need = pos // self.layout.block_size + 1
        while self.counts[slot] < need:
            self.table[slot, self.counts[slot]] = self.alloc.alloc()
            self.counts[slot] += 1
            self.dirty = True

    def adopt(self, slot: int, bids: Sequence[int]):
        """Alias shared prefix blocks into a freshly-claimed slot's table
        (incref each) — the slot's row must be empty (claim time)."""
        if int(self.counts[slot]):
            raise RuntimeError(
                f"adopt into slot {slot} which already holds "
                f"{int(self.counts[slot])} blocks (adopt is claim-time only)")
        for j, bid in enumerate(bids):
            self.table[slot, j] = self.alloc.incref(bid)
        self.counts[slot] = len(bids)
        if bids:
            self.dirty = True

    def release(self, slot: int):
        """Release a retired slot's blocks (one decref each — shared blocks
        stay resident for their other owners or the prefix cache); its row
        falls back to scratch 0."""
        n = int(self.counts[slot])
        if n:
            self.alloc.free(self.table[slot, :n].tolist())
            self.table[slot, :n] = 0
            self.counts[slot] = 0
            self.dirty = True

    def device_table(self) -> jnp.ndarray:
        self.dirty = False
        return jnp.asarray(self.table)


# ---------------------------------------------------------------------------
# Prefix cache: radix index over full KV blocks
# ---------------------------------------------------------------------------

class _RadixNode:
    """One FULL block of cached history.  ``key`` is the block's
    ``block_size``-token tuple; the path from the root spells the whole
    token prefix the block's KV content was computed from (KV at position p
    depends causally on tokens[0..p], so content identity == path
    identity)."""

    __slots__ = ("key", "block", "children", "parent", "tick")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_RadixNode"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.tick = 0


class PrefixCache:
    """Radix/trie index mapping token prefixes to resident pool blocks.

    Only FULL blocks are indexed (a partially-filled block's content keeps
    changing while its slot appends).  ``match`` walks the trie with a
    prompt and returns the longest chain of cached blocks plus how many
    tokens it covers — the last chain block may match only partially (the
    prompt diverges mid-block), which the scheduler resolves with a
    copy-on-write block copy.  ``insert`` registers a retired (or
    prompt-complete) slot's full blocks; the allocator's ``retain`` hook
    then parks their refcount-0 blocks instead of freeing them.  ``evict``
    drops least-recently-matched LEAF nodes whose blocks have no live
    owners — leaf-first keeps every remaining node's path intact, and the
    allocator calls it via ``reclaim`` under pool pressure, so cached
    prefixes never cost capacity.

    Counters (``hits`` / ``misses`` / ``tokens_reused`` / ``cow_copies`` /
    ``evictions``) are plain ints the scheduler mirrors onto the metrics
    registry each iteration."""

    def __init__(self, alloc: BlockAllocator, block_size: int,
                 min_blocks: int = 1):
        if min_blocks < 1:
            raise ValueError(f"min_blocks must be >= 1, got {min_blocks}")
        self.alloc = alloc
        self.block_size = int(block_size)
        self.min_blocks = int(min_blocks)
        self.root = _RadixNode((), 0, None)
        self.by_block: Dict[int, _RadixNode] = {}
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0
        self.cow_copies = 0
        self.evictions = 0
        alloc.retain = self.by_block.__contains__
        alloc.reclaim = self.evict

    @property
    def resident_blocks(self) -> int:
        """Blocks the radix currently keeps resident (live-shared + parked)."""
        return len(self.by_block)

    def _touch(self, node: _RadixNode):
        self._tick += 1
        node.tick = self._tick

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached chain for ``tokens``: returns (block ids, matched
        token count).  All chain blocks are full-block matches except
        possibly the last, which may cover only ``matched % block_size``
        leading tokens (mid-block divergence).  Touches every node on the
        chain (LRU recency) but takes no references — the caller increfs
        what it actually adopts."""
        bs = self.block_size
        toks = tuple(int(t) for t in tokens)
        node, chain, i = self.root, [], 0
        while True:
            key = toks[i:i + bs]
            child = node.children.get(key) if len(key) == bs else None
            if child is not None:
                self._touch(child)
                chain.append(child.block)
                node, i = child, i + bs
                continue
            # no full-block child: find the longest partial boundary match
            best, best_n = None, 0
            rest = toks[i:]
            if rest:
                for ckey, cnode in node.children.items():
                    n = 0
                    for a, b in zip(ckey, rest):
                        if a != b:
                            break
                        n += 1
                    if n > best_n:
                        best, best_n = cnode, n
            if best is not None:
                self._touch(best)
                chain.append(best.block)
                i += best_n
            return chain, i

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Register ``blocks`` (full blocks only — ``len(tokens)`` must be
        ``len(blocks) * block_size``) under the token path.  Existing nodes
        win: a block whose path is already cached is NOT re-registered (the
        duplicate stays slot-private and frees on retire).  Returns how many
        new nodes were created."""
        bs = self.block_size
        toks = tuple(int(t) for t in tokens)
        if len(toks) != len(blocks) * bs:
            raise ValueError(
                f"insert of {len(blocks)} blocks needs exactly "
                f"{len(blocks) * bs} tokens, got {len(toks)}")
        node, created = self.root, 0
        for j, bid in enumerate(blocks):
            key = toks[j * bs:(j + 1) * bs]
            child = node.children.get(key)
            if child is None:
                bid = int(bid)
                if bid in self.by_block:
                    # the block already backs a different path — allocator
                    # corruption upstream; never index one block twice
                    raise RuntimeError(
                        f"block {bid} is already registered in the prefix "
                        "index under a different token path")
                child = _RadixNode(key, bid, node)
                node.children[key] = child
                self.by_block[bid] = child
                created += 1
            self._touch(child)
            node = child
        return created

    def _evictable(self) -> Optional[_RadixNode]:
        """Least-recently-matched LEAF whose block has no live owners."""
        best = None
        for bid, node in self.by_block.items():
            if node.children or self.alloc.refcount(bid):
                continue
            if best is None or node.tick < best.tick:
                best = node
        return best

    def evict(self, n: int = 1) -> int:
        """Drop up to ``n`` LRU refcount-0 leaf blocks back to the free
        list; returns how many were freed.  Evicting a leaf may expose its
        parent as the next candidate, so deep cold chains drain tail-first
        without ever breaking a surviving node's path."""
        freed = 0
        while freed < n:
            node = self._evictable()
            if node is None:
                break
            del node.parent.children[node.key]
            del self.by_block[node.block]
            self.alloc.release_parked(node.block)
            self.evictions += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Evict every evictable block (tests / explicit cache drop)."""
        return self.evict(len(self.by_block))


def copy_block(cache, src, dst):
    """Copy one GLOBAL-attention pool block ``src`` -> ``dst`` across every
    layer of the cache pytree — the copy-on-write step for a partially
    matched boundary block.  ``src``/``dst`` may be traced scalars (one
    compiled program covers every pair).  Sliding-window layer pools (the
    ``lt``-carrying dicts) are layer-private rings outside the shared table
    and are left untouched; recurrent state isn't block-structured at all —
    both are why the scheduler only enables prefix sharing for global-
    attention-only stacks."""
    def walk(node):
        if isinstance(node, dict):
            if "kp" in node and "lt" not in node:
                # leaves are [NB, bs, ...] or scan-stacked [R, NB, bs, ...];
                # GLVQ codebook leaves (kg/kgi/kmu/...) are per-layer
                # constants shared by every block and stay out of the copy

                return {k: copy_pool_block(
                            v, src, dst,
                            stacked=v.ndim == (5 if k in ("kp", "vp") else 4))
                        if k in ("kp", "vp", "ksc", "vsc") else v
                        for k, v in node.items()}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(cache)


def static_table(batch: int, blocks_per_slot: int) -> jnp.ndarray:
    """Fully-preallocated contiguous table (row b owns blocks
    [1 + b*bps, 1 + (b+1)*bps)) — for plain batched decode loops that don't
    run an allocator (``launch.serve`` demo, benchmarks)."""
    base = 1 + blocks_per_slot * np.arange(batch)[:, None]
    return jnp.asarray(base + np.arange(blocks_per_slot)[None], jnp.int32)


# ---------------------------------------------------------------------------
# Analytic byte accounting (benchmarks + capacity planning)
# ---------------------------------------------------------------------------

def _attn_layers(cfg: ModelConfig, s_cache: int) -> List[tuple]:
    """Per attention layer: (retained positions, is_sliding_window)."""
    out = []
    kinds = list(cfg.scan_unit) * cfg.n_repeats + list(cfg.scan_tail)
    for kind in kinds:
        if kind in _ATTN_KINDS:
            if kind == "attn_local" and cfg.window:
                out.append((min(cfg.window, s_cache), True))
            else:
                out.append((s_cache, False))
    return out


def attn_layer_lengths(cfg: ModelConfig, s_cache: int) -> List[int]:
    """Per attention layer: how many cache positions it retains (global
    layers keep s_cache; sliding-window layers keep min(window, s_cache))."""
    return [s for s, _ in _attn_layers(cfg, s_cache)]


def _per_pos_bytes(cfg: ModelConfig, kind: str, dtype_bytes: int,
                   kv_bits: int = 4) -> float:
    """K+V bytes for one retained position of one attention layer."""
    per_head = cfg.n_kv_heads * cfg.hd
    if kind in ("dense", "paged"):
        return 2 * per_head * dtype_bytes
    if kind == "paged_glvq":
        # uint32 word-packed lattice codes + f16 per-token-per-head amax
        words = packing.packed_len(cfg.hd, kv_bits)
        return 2 * (cfg.n_kv_heads * 4 * words + cfg.n_kv_heads * 2)
    # int8 codes + f16 per-token-per-head scale
    return 2 * (per_head * 1 + cfg.n_kv_heads * 2)


def cache_bytes(cfg: ModelConfig, kind: str, seq_len: int, s_cache: int,
                block_size: int = 16, dtype_bytes: int = 2,
                kv_bits: int = 4) -> int:
    """Resident attention-cache bytes for ONE slot holding ``seq_len`` tokens.

    Dense reserves every layer's full retained length up front.  Paged
    GLOBAL layers only hold the blocks the sequence has actually touched
    (lazy allocator grants); paged SLIDING-WINDOW layers statically own
    their whole ring — ``ceil(min(window, s_cache) / block_size)`` blocks
    per slot in a layer-private pool from init (``models.layers.
    paged_attn_cache_init``) — so their bytes never scale with seq_len."""
    if kind not in CACHE_KINDS:
        raise ValueError(f"unknown cache kind {kind!r}; "
                         f"available: {CACHE_KINDS}")
    total = 0.0
    for s_layer, local in _attn_layers(cfg, s_cache):
        if kind == "dense":
            total += s_layer * _per_pos_bytes(cfg, kind, dtype_bytes, kv_bits)
        else:
            if local:
                blocks = -(-s_layer // block_size)     # static ring ownership
            else:
                touched = min(seq_len, s_layer)
                blocks = -(-touched // block_size) if touched else 0
            total += blocks * block_size * _per_pos_bytes(cfg, kind,
                                                          dtype_bytes, kv_bits)
    if kind != "dense":
        total += 4 * (-(-s_cache // block_size))      # int32 table row
    return int(total)


def bytes_per_token(cfg: ModelConfig, kind: str, seq_len: int, s_cache: int,
                    block_size: int = 16, dtype_bytes: int = 2,
                    kv_bits: int = 4) -> float:
    """Resident cache bytes per stored token at sequence length ``seq_len``."""
    return cache_bytes(cfg, kind, seq_len, s_cache, block_size,
                       dtype_bytes, kv_bits) / max(seq_len, 1)


def codebook_bytes(cfg: ModelConfig, kind: str, kv_bits: int = 4,
                   kv_d: int = 0) -> int:
    """Resident GLVQ codebook overhead: the f32 generation-matrix leaves
    (kg/kgi/vg/vgi ``[KV, d, d]`` + kmu/vmu ``[KV]``) every attention layer
    carries in its pool.  Shared by ALL slots (and never copied by CoW), so
    it is a flat per-model constant, not part of bytes/token.  0 for every
    other cache kind."""
    if kind != "paged_glvq":
        return 0
    spec = default_glvq_spec(cfg.hd, bits=kv_bits, d=kv_d or None)
    per_layer = (4 * cfg.n_kv_heads * spec.d * spec.d
                 + 2 * cfg.n_kv_heads) * 4
    return per_layer * len(_attn_layers(cfg, 1))


def max_resident_slots(cfg: ModelConfig, kind: str, hbm_bytes: float,
                       seq_len: int, s_cache: int, block_size: int = 16,
                       dtype_bytes: int = 2, kv_bits: int = 4) -> int:
    """How many concurrent slots at ``seq_len`` fit a fixed cache budget."""
    per_slot = cache_bytes(cfg, kind, seq_len, s_cache, block_size,
                           dtype_bytes, kv_bits)
    return int(hbm_bytes // max(per_slot, 1))
