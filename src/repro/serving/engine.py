"""``repro.serving.engine`` — the serving front door.

``EngineConfig`` is ONE frozen object for every execution knob that used to
thread through ``ContinuousBatcher.__init__`` / ``registry.chunk_step`` /
``launch/serve.py`` as loose kwargs: model execution (dtype / qmeta /
backend / unroll / mesh), attention cache (cache_kind / block_size /
num_blocks / kv_backend / s_cache), and scheduling (slots / chunk_size /
pad_token / default stop tokens).  ``registry.chunk_step`` / ``decode_step``
/ ``cache_init`` and the scheduler all consume it directly; the loose-kwarg
spellings survive only as back-compat shims.

``ServingEngine`` is the user-facing facade on top of the continuous
batcher:

    engine = ServingEngine(params, cfg, EngineConfig(s_cache=128,
                                                     chunk_size=32))
    handle = engine.submit(prompt, SamplingParams(temperature=0.8, seed=7))
    for tok in handle:                  # streams as the engine iterates
        ...
    # or drive everything and watch all slots:
    for event in engine.stream():       # TokenEvent(rid, token, index, ...)
        ...
    req = engine.generate(prompt)       # blocking convenience

Sampling runs inside the compiled serving step (see ``serving.sampling``),
so each iteration ships ``[B]`` token ids to the host, never ``[B, vocab]``
logits.  Finished requests carry ``done_reason``: ``"length"`` (hit the
token cap), ``"stop_token"``, or ``"cache_full"`` (ran out of cache
positions).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.serving import kvcache
from repro.serving.policy import SchedulerPolicy
from repro.serving.sampling import SamplingParams

__all__ = ["EngineConfig", "TokenEvent", "RequestHandle", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every serving-execution knob in one immutable object.

    Model execution: ``dtype`` (activation dtype), ``qmeta`` (packed GLVQ
    payload metadata; enables the QuantTensor engine), ``backend`` (name
    from ``kernels.ops.matmul_backends()``; None = platform default),
    ``unroll`` (scan unroll), ``mesh`` (tensor-parallel shard_map mesh).

    Attention cache: ``cache_kind`` (dense | paged | paged_q8 | paged_q8c |
    paged_glvq), ``kv_bits`` / ``kv_d`` / ``kv_codebook`` (the paged_glvq
    lattice codec: coordinate bit-width, sub-vector dim — 0 = auto — and an
    optional calibrated ``data.calibration.KVCodebook``, whose bits/d
    override the scalars when set; without one the identity codebook makes
    paged_glvq exact uniform signed-kv_bits quantization),
    ``block_size`` / ``num_blocks`` (paged pool geometry; ``num_blocks``
    None = planned from ``s_cache`` x ``slots``), ``kv_backend`` (name from
    ``kernels.kv_cache.kv_backends()``), ``attn_backend`` (name from
    ``kernels.attention.attn_backends()``: ``pallas`` = fused block-walk +
    dequant + flash SDPA, ``xla`` = gather-then-SDPA; None = platform
    default), ``s_cache`` (cache positions per slot; None lets model-level
    calls infer capacity, the scheduler defaults it to 64),
    ``prefix_cache`` (radix prefix caching over the paged pool: requests
    whose prompts share a prefix alias the cached KV blocks read-only and
    prefill only from the divergence point, with copy-on-write for a
    mid-block boundary and LRU eviction of unreferenced cached blocks under
    pool pressure; needs a paged ``cache_kind``, and sharing engages only
    for global-attention stacks — recurrent state and sliding-window rings
    cannot be reconstructed from aliased blocks), ``prefix_cache_min_blocks``
    (smallest full-block match worth taking — shorter matches are treated
    as misses so tiny shared stubs don't churn the pool with CoW copies).

    Scheduling: ``slots`` (concurrent batch lanes), ``chunk_size`` (max
    prompt tokens one iteration may consume per slot), ``pad_token``,
    ``stop_tokens`` (engine-wide default stop ids, merged with each
    request's ``SamplingParams.stop_token_ids``), ``topk_logprobs`` (attach
    the top-k alternative logprobs to every ``TokenEvent``; the sampled
    token's own logprob always rides along).

    Observability: ``metrics`` (record request/iteration/cache telemetry
    into the batcher's ``serving.metrics.MetricsRegistry``; host-side only,
    on by default — ``metrics=False`` skips every recording call and leaves
    the jitted step byte-identical), ``trace`` (turn on
    ``serving.trace`` xprof annotations: named scopes around ``chunk_step``
    / ``paged_attention`` / ``append_chunk`` dispatch plus host spans per
    engine iteration), ``sync_timing`` (``block_until_ready`` inside the
    per-iteration dispatch timer, trading pipelining for honest host-side
    step latencies), ``debug_checks`` (the ``repro.analysis.runtime``
    sanitizer: checkify assertions traced INTO the jitted step — block-table
    ids in range, position bounds, finite logprobs — plus host-side
    allocator-aliasing and recompile-storm detection each iteration; a trip
    raises ``DebugCheckError`` and counts
    ``serving_debug_check_failures_total{check=}``.  Off by default and
    graph-free when off: the compiled step is byte-identical).
    """
    # model execution
    dtype: Any = jnp.bfloat16
    qmeta: Any = None
    backend: Optional[str] = None
    unroll: int = 1
    mesh: Any = None
    # attention cache
    cache_kind: str = "dense"
    block_size: int = 16
    num_blocks: Optional[int] = None
    kv_backend: Optional[str] = None
    attn_backend: Optional[str] = None
    s_cache: Optional[int] = None
    kv_bits: int = 4
    kv_d: int = 0
    kv_codebook: Any = dataclasses.field(default=None, compare=False,
                                         repr=False)
    prefix_cache: bool = False
    prefix_cache_min_blocks: int = 1
    # scheduling
    slots: int = 4
    chunk_size: int = 1
    pad_token: int = 0
    stop_tokens: Tuple[int, ...] = ()
    topk_logprobs: int = 0
    # observability
    metrics: bool = True
    trace: bool = False
    sync_timing: bool = False
    debug_checks: bool = False

    def __post_init__(self):
        if self.cache_kind not in kvcache.CACHE_KINDS:
            raise ValueError(f"unknown cache_kind {self.cache_kind!r}; "
                             f"available: {kvcache.CACHE_KINDS}")
        if self.attn_backend is not None:
            from repro.kernels.attention import attn_backends
            if self.attn_backend not in attn_backends():
                raise ValueError(
                    f"unknown attn_backend {self.attn_backend!r}; "
                    f"available: {attn_backends()}")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.topk_logprobs < 0:
            raise ValueError(f"topk_logprobs must be >= 0, "
                             f"got {self.topk_logprobs}")
        if self.prefix_cache_min_blocks < 1:
            raise ValueError(f"prefix_cache_min_blocks must be >= 1, "
                             f"got {self.prefix_cache_min_blocks}")
        kv_bits, kv_d = self.kv_bits, self.kv_d
        if self.kv_codebook is not None:
            # a calibrated codebook is authoritative for the codec geometry
            kv_bits = int(getattr(self.kv_codebook, "bits", kv_bits))
            kv_d = int(getattr(self.kv_codebook, "d", kv_d))
        if not 2 <= kv_bits <= 8:
            raise ValueError(f"kv_bits must be in [2, 8], got {kv_bits}")
        for field, value in (("kv_bits", kv_bits), ("kv_d", kv_d),
                             ("stop_tokens",
                              tuple(int(t) for t in self.stop_tokens))):
            object.__setattr__(self, field, value)

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class TokenEvent:
    """One generated token, surfaced per engine iteration per live slot.

    ``logprob`` is the sampled token's log-probability under the model
    distribution (raw chunk-final logits, independent of temperature /
    top-k / top-p), gathered in-graph so only scalars cross the host
    boundary.  ``top_logprobs`` carries the ``EngineConfig.topk_logprobs``
    most likely (token_id, logprob) alternatives, or None when disabled."""
    rid: int
    token: int
    index: int                      # position in the request's output stream
    done: bool = False
    done_reason: Optional[str] = None
    logprob: Optional[float] = None
    top_logprobs: Optional[Tuple[Tuple[int, float], ...]] = None


class RequestHandle:
    """Live view of one submitted request.

    Iterating the handle drives the engine until THIS request finishes,
    yielding its token ids as they are generated (other slots advance on the
    same iterations — streaming one request never starves the rest).
    """

    def __init__(self, engine: "ServingEngine", request):
        self._engine = engine
        self.request = request

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def tokens(self) -> List[int]:
        return list(self.request.tokens)

    @property
    def done(self) -> bool:
        return self.request.done

    @property
    def done_reason(self) -> Optional[str]:
        return self.request.done_reason

    def result(self, max_steps: int = 100_000):
        """Block until this request finishes; returns the finished Request."""
        steps = 0
        while not self.request.done and steps < max_steps:
            if not self._engine.batcher.pending():
                raise RuntimeError(
                    f"request {self.rid} cannot finish: the engine has no "
                    "pending work (was it already retired elsewhere?)")
            self._engine.step()
            steps += 1
        if not self.request.done:
            raise RuntimeError(f"request {self.rid} still unfinished after "
                               f"{max_steps} engine iterations")
        return self.request

    def __iter__(self) -> Iterator[int]:
        emitted = 0
        while True:
            toks = self.request.tokens
            while emitted < len(toks):
                yield toks[emitted]
                emitted += 1
            if self.request.done:
                return
            if not self._engine.batcher.pending():
                return
            self._engine.step()


class ServingEngine:
    """Facade over the continuous batcher: submit / stream / generate."""

    def __init__(self, params, cfg, engine: Optional[EngineConfig] = None, *,
                 policy: Optional[SchedulerPolicy] = None,
                 default_params: Optional[SamplingParams] = None,
                 trace_log=None):
        # local import: scheduler imports this module for EngineConfig
        from repro.serving.scheduler import ContinuousBatcher
        self.config = engine if engine is not None else EngineConfig()
        self.batcher = ContinuousBatcher(params, cfg, self.config,
                                         policy=policy,
                                         default_params=default_params,
                                         trace_log=trace_log)
        self._next_rid = 0
        self.handles: dict = {}

    @property
    def policy(self) -> SchedulerPolicy:
        return self.batcher.policy

    @property
    def metrics(self):
        """The batcher's ``serving.metrics.MetricsRegistry``."""
        return self.batcher.metrics

    def metrics_snapshot(self) -> dict:
        """Nested plain-dict view of every serving metric (counters /
        gauges / histograms incl. TTFT, queue wait, inter-token latency,
        block-pool occupancy, done_reason and compile-event counts)."""
        return self.batcher.metrics.snapshot()

    def render_prometheus(self) -> str:
        """Prometheus text-format rendering of the same registry (what
        ``launch/serve.py --metrics-port`` serves at ``/metrics``)."""
        return self.batcher.metrics.render_prometheus()

    def prefix_cache_stats(self) -> Optional[dict]:
        """Live prefix-cache counters, or None when the cache is off (or
        the model's stack cannot share blocks: recurrent / sliding-window
        state is not reconstructable from aliased pool blocks)."""
        pc = self.batcher.prefix
        if pc is None:
            return None
        return {"hits": pc.hits, "misses": pc.misses,
                "tokens_reused": pc.tokens_reused,
                "cow_copies": pc.cow_copies, "evictions": pc.evictions,
                "resident_blocks": pc.resident_blocks}

    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None,
               rid: Optional[int] = None) -> RequestHandle:
        """Queue one request; returns a streaming handle.

        The request's token cap is ``params.max_tokens`` when set, else
        whatever fits the cache (it then finishes with done_reason
        "cache_full" unless a stop token lands first).
        """
        from repro.serving.scheduler import Request
        if rid is None:
            rid = self._next_rid
        if rid in self.handles:
            raise ValueError(f"request id {rid} is still in flight")
        self._next_rid = max(self._next_rid, rid) + 1
        params = params if params is not None else self.batcher.default_params
        # max_tokens unset -> run until the cache fills (or a stop token);
        # the cap is deliberately past the cache so the request retires with
        # done_reason "cache_full", not "length"
        max_new = params.max_tokens if params.max_tokens is not None \
            else self.batcher.s_cache
        req = Request(rid=rid, prompt=list(map(int, prompt)),
                      max_new=max_new, params=params)
        self.batcher.submit(req)
        handle = RequestHandle(self, req)
        self.handles[rid] = handle
        return handle

    def step(self) -> List[TokenEvent]:
        """One engine iteration; returns the tokens it produced.

        Finished requests are evicted from ``handles`` (the handle object a
        caller holds keeps working — it references the Request directly), so
        a long-running engine doesn't pin every request it ever served; the
        rid becomes reusable.  ``batcher.finished`` still accumulates
        results for ``run()``/``generate()`` callers — a persistent server
        should drain or clear it periodically."""
        events = self.batcher.step()
        for ev in events:
            if ev.done:
                self.handles.pop(ev.rid, None)
        return events

    def stream(self, max_steps: int = 100_000) -> Iterator[TokenEvent]:
        """Drive the engine until idle, yielding every TokenEvent in order."""
        steps = 0
        while self.batcher.pending() and steps < max_steps:
            yield from self.step()
            steps += 1

    def generate(self, prompt: Sequence[int],
                 params: Optional[SamplingParams] = None):
        """Blocking convenience: submit + drain; returns the finished
        Request (tokens + done_reason)."""
        return self.submit(prompt, params).result()

    def run(self, max_steps: int = 10_000):
        """Drain all queued work; returns {rid: finished Request}."""
        steps = 0
        while self.batcher.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.batcher.finished
