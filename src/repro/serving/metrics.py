"""Zero-dependency serving telemetry: counters, gauges, histograms + export.

One ``MetricsRegistry`` is threaded through the whole serving path
(``ContinuousBatcher`` owns one, ``ServingEngine.metrics_snapshot()``
surfaces it); everything here is stdlib-only and host-side — recording a
sample never touches a traced value or a compiled program, so metrics can
stay on by default (the ``benchmarks/serving.py`` overhead gate asserts
< 2% tokens/s cost).

Three metric kinds:

  * ``Counter``   — monotone event count, optionally mirroring an external
    cumulative source (``set_cumulative``, used for the ``BlockAllocator``
    alloc/free totals).
  * ``Gauge``     — last-set value + high-water mark (block-pool occupancy,
    modeled resident cache bytes).
  * ``Histogram`` — fixed log-spaced buckets (latency-shaped by default:
    100 us .. ~100 s) with count / sum / min / max and bucket-interpolated
    percentiles (``p50``/``p95``/``p99`` in every snapshot — the serving
    bench records tail inter-token latency from here, not from its own
    timers).

Export surfaces: ``snapshot()`` (nested plain dict, JSON-ready),
``render_prometheus()`` (text exposition format), ``serve_http()`` (stdlib
``http.server`` thread serving ``/metrics`` + ``/metrics.json`` — the
``launch/serve.py --metrics-port`` endpoint).

``Timer`` + ``log_event`` are the shared timing/structured-logging helpers
the launch drivers use instead of ad-hoc ``time.time()`` prints (a repo
lint pins that: rule R1 in ``repro.analysis``).
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["log_buckets", "LATENCY_BUCKETS", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "Timer", "log_event", "serve_http"]


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds: ``per_decade`` per power of ten,
    from ``lo`` up to the first bound >= ``hi``."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = 0
    out: List[float] = []
    while True:
        b = lo * 10.0 ** (n / per_decade)
        out.append(b)
        if b >= hi:
            return tuple(out)
        n += 1


# 100 us .. ~100 s, 3 buckets per decade: wide enough for a compile-included
# first iteration at the top and a fused decode step at the bottom.
LATENCY_BUCKETS = log_buckets(1e-4, 100.0, 3)


class Counter:
    """Monotone event counter."""

    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n

    def set_cumulative(self, total: float):
        """Mirror an external monotone total (e.g. ``BlockAllocator.
        total_allocs``) — never moves backwards."""
        self.value = max(self.value, float(total))

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Last-set value, plus the high-water mark since creation."""

    kind = "gauge"

    def __init__(self):
        self.value = 0.0
        self.high_water = 0.0

    def set(self, v: float):
        self.value = float(v)
        if self.value > self.high_water:
            self.high_water = self.value

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram with interpolated percentile estimates."""

    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = LATENCY_BUCKETS):
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.bounds) + 1)   # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float):
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, p: float) -> Optional[float]:
        """Bucket-interpolated p-th percentile (p in [0, 100]); exact-ish
        for anything the bucket resolution can see, clamped to observed
        min/max so a one-sample histogram reports that sample."""
        if not self.count:
            return None
        rank = p / 100.0 * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (rank - seen) / c
                est = lo + (hi - lo) * max(min(frac, 1.0), 0.0)
                return max(min(est, self.max), self.min)
            seen += c
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        return dict(
            count=self.count, sum=self.sum,
            min=self.min if self.count else None,
            max=self.max if self.count else None,
            mean=self.sum / self.count if self.count else None,
            p50=self.percentile(50), p95=self.percentile(95),
            p99=self.percentile(99),
            buckets={("+Inf" if i == len(self.bounds)
                      else repr(self.bounds[i])): c
                     for i, c in enumerate(self.counts)},
        )


_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Name + labels -> metric instance; the one store every serving layer
    records into.  Metric creation is get-or-create (idempotent), so call
    sites never coordinate; a name must keep one kind for its lifetime."""

    def __init__(self):
        self._metrics: Dict[str, Dict[_LabelKey, Any]] = {}
        self._kinds: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._lock = threading.Lock()    # the HTTP exporter reads cross-thread

    def _get(self, name: str, labels: Dict[str, Any], factory, kind: str):
        with self._lock:
            have = self._kinds.get(name)
            if have is not None and have != kind:
                raise ValueError(f"metric {name!r} is a {have}, not a {kind}")
            self._kinds[name] = kind
            fam = self._metrics.setdefault(name, {})
            key = _label_key(labels)
            m = fam.get(key)
            if m is None:
                m = fam[key] = factory()
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        if help:
            self._help.setdefault(name, help)
        return self._get(name, labels, Counter, "counter")

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        if help:
            self._help.setdefault(name, help)
        return self._get(name, labels, Gauge, "gauge")

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS,
                  **labels) -> Histogram:
        if help:
            self._help.setdefault(name, help)
        return self._get(name, labels, lambda: Histogram(buckets), "histogram")

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Nested plain dict (JSON-ready): kind -> name -> {label-string ->
        value/stats}.  Label string is ``k=v,k2=v2`` ("" for no labels)."""
        out: Dict[str, Any] = dict(counters={}, gauges={}, histograms={})
        with self._lock:
            for name, fam in sorted(self._metrics.items()):
                kind = self._kinds[name]
                dst = out[{"counter": "counters", "gauge": "gauges",
                           "histogram": "histograms"}[kind]]
                dst[name] = {
                    ",".join(f"{k}={v}" for k, v in key): m.snapshot()
                    for key, m in sorted(fam.items())}
                if kind == "gauge":
                    dst[name + "__high_water"] = {
                        ",".join(f"{k}={v}" for k, v in key): m.high_water
                        for key, m in sorted(fam.items())}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        def esc(v: str) -> str:
            return v.replace("\\", r"\\").replace('"', r'\"') \
                    .replace("\n", r"\n")

        def labelstr(key: _LabelKey, extra: Tuple[Tuple[str, str], ...] = ()):
            items = key + extra
            if not items:
                return ""
            return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in items) + "}"

        lines: List[str] = []
        with self._lock:
            for name, fam in sorted(self._metrics.items()):
                kind = self._kinds[name]
                if name in self._help:
                    lines.append(f"# HELP {name} {esc(self._help[name])}")
                lines.append(f"# TYPE {name} {kind}")
                for key, m in sorted(fam.items()):
                    if kind in ("counter", "gauge"):
                        lines.append(f"{name}{labelstr(key)} {m.value:g}")
                        continue
                    cum = 0
                    for i, c in enumerate(m.counts):
                        cum += c
                        le = "+Inf" if i == len(m.bounds) \
                            else f"{m.bounds[i]:g}"
                        lines.append(
                            f"{name}_bucket{labelstr(key, (('le', le),))} "
                            f"{cum}")
                    lines.append(f"{name}_sum{labelstr(key)} {m.sum:g}")
                    lines.append(f"{name}_count{labelstr(key)} {m.count}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Timing + structured logging helpers (the launch drivers' shared clock)
# ---------------------------------------------------------------------------

class Timer:
    """Monotonic wall-clock timer: one object for elapsed-so-far, split
    laps, and (as a context manager) recording a span into a histogram.

        tm = Timer()
        ...lower...
        t_lower = tm.lap()
        ...compile...
        t_compile = tm.lap()          # since the previous lap

        with Timer(hist):             # observes the span on exit
            step()
    """

    def __init__(self, hist: Optional[Histogram] = None):
        self._hist = hist
        self.start = time.perf_counter()
        self._last = self.start
        self.elapsed = 0.0

    @property
    def total(self) -> float:
        return time.perf_counter() - self.start

    def lap(self) -> float:
        now = time.perf_counter()
        dt = now - self._last
        self._last = now
        return dt

    def __enter__(self) -> "Timer":
        self.start = time.perf_counter()
        self._last = self.start
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start
        if self._hist is not None:
            self._hist.observe(self.elapsed)
        return False


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        a = abs(v)
        if a and (a < 1e-3 or a >= 1e5):
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def log_event(tag: str, **fields):
    """The one sanctioned CLI print: a structured ``[tag] k=v ...`` line.
    Launch drivers log timings through this (fed by ``Timer``), so every
    driver's output is grep-able the same way."""
    print(f"[{tag}] " + " ".join(f"{k}={_fmt(v)}" for k, v in fields.items()),
          flush=True)


# ---------------------------------------------------------------------------
# HTTP exporter (stdlib-only; the --metrics-port endpoint)
# ---------------------------------------------------------------------------

def serve_http(registry: MetricsRegistry, port: int, host: str = ""):
    """Serve ``/metrics`` (Prometheus text) + ``/metrics.json`` (snapshot)
    from a daemon thread.  Returns the ``HTTPServer`` — call ``shutdown()``
    to stop it; port 0 picks a free port (``server_address[1]`` has it)."""
    import http.server

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.split("?")[0] == "/metrics":
                body = registry.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.split("?")[0] == "/metrics.json":
                body = json.dumps(registry.snapshot(), indent=1).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):      # scrapes are not CLI output
            pass

    server = http.server.ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="metrics-http", daemon=True)
    thread.start()
    return server
