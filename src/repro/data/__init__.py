from repro.data import synthetic, calibration
