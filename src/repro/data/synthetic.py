"""Synthetic data pipeline.

Generates structured (learnable) token streams rather than iid noise so that
training curves are meaningful: a Markov-chain language with per-document
topic drift. Deterministic given the seed; shardable by host.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["markov_tokens", "token_batches", "make_batch"]


def _transition(vocab: int, seed: int, concentration: float = 0.05):
    rng = np.random.default_rng(seed)
    # sparse-ish row-stochastic transition with a few modes per token
    n_next = max(4, vocab // 16)
    nxt = rng.integers(0, vocab, size=(vocab, n_next))
    probs = rng.dirichlet(np.full(n_next, concentration), size=vocab)
    return nxt, probs


def markov_tokens(vocab: int, n: int, seed: int = 0) -> np.ndarray:
    nxt, probs = _transition(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    out = np.empty(n, np.int32)
    tok = int(rng.integers(0, vocab))
    for i in range(n):
        out[i] = tok
        j = rng.choice(probs.shape[1], p=probs[tok])
        tok = int(nxt[tok, j])
    return out


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int,
               stream: Optional[np.ndarray] = None) -> Dict[str, jnp.ndarray]:
    """One training batch for any family (handles vlm / enc-dec stubs)."""
    rng = np.random.default_rng(seed)
    if stream is None:
        toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1)).astype(np.int32)
    else:
        starts = rng.integers(0, len(stream) - seq - 1, size=batch)
        toks = np.stack([stream[s:s + seq + 1] for s in starts])
    if cfg.enc_layers:
        frames = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
        return dict(frames=jnp.asarray(frames),
                    tokens=jnp.asarray(toks[:, :seq]),
                    labels=jnp.asarray(toks[:, 1:seq + 1]))
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        st = max(seq - nv, 1)
        pos = np.tile(np.arange(st + nv), (3, batch, 1)).astype(np.int32)
        return dict(tokens=jnp.asarray(toks[:, :st]),
                    vision=jnp.asarray(
                        rng.normal(size=(batch, nv, cfg.d_model)).astype(np.float32)),
                    pos3=jnp.asarray(pos),
                    labels=jnp.asarray(toks[:, 1:st + 1]))
    return dict(tokens=jnp.asarray(toks[:, :seq]),
                labels=jnp.asarray(toks[:, 1:seq + 1]))


def token_batches(cfg: ModelConfig, batch: int, seq: int, steps: int,
                  seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Deterministic, resumable batch stream (step index == batch seed)."""
    stream = markov_tokens(cfg.vocab, max(batch * seq * 4, 65_536), seed)
    for step in range(steps):
        yield make_batch(cfg, batch, seq, seed * 100_003 + step, stream)
