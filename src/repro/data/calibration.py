"""Calibration capture + whole-model PTQ drivers (dense LM family).

Mirrors GPTQ-style calibration: run the model over calibration batches and
accumulate the second moment H = X^T X of every linear layer's input, then
quantize each weight with its own H. Reuses ``repro.models.layers`` for all
math; only the layer loop is reimplemented (python-level, unstacked) because
taps inside jax.lax.scan would change the core model code.

``quantize_model`` returns FAKE-QUANT (dequantized) params — the accuracy
evaluation path of the paper's Tables 1-3. Packed serving payloads come from
``repro.core.quantized.quantize_param_tree``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sdba import group_salience, fractional_bits, sdba as sdba_fn
from repro.core.baselines import gptq_quantize, rtn_quantize, fixed_lattice_init
from repro.core.glvq import GLVQConfig, quantize_group, quantize_layer, \
    dequantize_layer
from repro.kernels import kv_cache
from repro.models import layers
from repro.models.layers import rms_norm

__all__ = ["collect_h", "quantize_model", "layer_slice", "layer_set",
           "KVCodebook", "calibrate_kv", "save_kv_codebook",
           "load_kv_codebook"]


def layer_slice(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def layer_set(tree, i: int, sub):
    return jax.tree.map(lambda a, s: a.at[i].set(s), tree, sub)


def _dense_taps(params, batch, cfg: ModelConfig, dtype=jnp.float32):
    """Forward pass emitting per-layer linear inputs (dense/vlm families)."""
    from repro.models import lm
    x, pos = lm.embed_inputs(params, batch, cfg, dtype)
    taps: List[Dict[str, jnp.ndarray]] = []
    n_rep = cfg.n_heads // cfg.n_kv_heads
    r = cfg.n_repeats
    assert cfg.scan_unit == ("attn",), "calibration taps: dense family only"
    blocks = params["blocks"][0]
    for i in range(r):
        p = layer_slice(blocks, i)
        t = {}
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        t["attn_in"] = h
        q, k, v = layers._qkv(p["attn"], h, cfg, pos)
        mask = jnp.tril(jnp.ones((h.shape[1], h.shape[1]), jnp.bool_))[None, None, None]
        o = layers._sdpa(q, k, v, mask, n_rep).reshape(h.shape[0], h.shape[1], -1)
        t["attn_mid"] = o
        x = x + o @ p["attn"]["wo"].astype(dtype)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        t["mlp_in"] = h
        m = h @ p["mlp"]["w1"].astype(dtype)
        if cfg.act == "swiglu":
            m = jax.nn.silu(m) * (h @ p["mlp"]["w3"].astype(dtype))
        elif cfg.act == "sq_relu":
            m = jnp.square(jax.nn.relu(m))
        else:
            m = jax.nn.gelu(m)
        t["mlp_mid"] = m
        x = x + m @ p["mlp"]["w2"].astype(dtype)
        taps.append(t)
    return taps

_TAP_OF_WEIGHT = dict(wq="attn_in", wk="attn_in", wv="attn_in", wo="attn_mid",
                      w1="mlp_in", w3="mlp_in", w2="mlp_mid")
_GROUP_OF_WEIGHT = dict(wq="attn", wk="attn", wv="attn", wo="attn",
                        w1="mlp", w3="mlp", w2="mlp")


def collect_h(params, batches: Iterable[dict], cfg: ModelConfig):
    """Accumulate H = X^T X per (layer, tap). Returns h[layer][tap] (np)."""
    acc: List[Dict[str, np.ndarray]] = []
    n = 0
    for batch in batches:
        taps = _dense_taps(params, batch, cfg)
        for i, t in enumerate(taps):
            if len(acc) <= i:
                acc.append({})
            for k, v in t.items():
                flat = np.asarray(v, np.float64).reshape(-1, v.shape[-1])
                h = flat.T @ flat
                acc[i][k] = acc[i].get(k, 0.0) + h
        n += 1
    return acc


@dataclasses.dataclass
class QuantReport:
    method: str
    bits: float
    layer_mse: List[float]


def quantize_model(params, cfg: ModelConfig, *, method: str = "glvq",
                   qcfg: Optional[GLVQConfig] = None,
                   h_acc: Optional[list] = None,
                   bits: Optional[float] = None):
    """Fake-quant every transformer linear; returns (new_params, report).

    method: glvq | glvq+ | glvq-u | rtn | gptq | fixed-lattice | gcd
    ("glvq+" = beyond-paper: per-output-column RMS normalization before the
    lattice, absorbing per-channel dynamic range like AWQ/RTN scales do.)
    ``bits`` may be fractional for glvq (SDBA mixes widths per Sec 4.3).
    """
    qcfg = qcfg or GLVQConfig()
    bits = bits if bits is not None else float(qcfg.bits)
    blocks = params["blocks"][0]
    r = cfg.n_repeats
    new_blocks = blocks
    mses = []
    for i in range(r):
        p = layer_slice(blocks, i)
        for grp, wname in [("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
                           ("attn", "wo"), ("mlp", "w1"), ("mlp", "w3"),
                           ("mlp", "w2")]:
            if wname not in p[grp]:
                continue
            w = p[grp][wname]
            h = None
            if h_acc is not None:
                h = jnp.asarray(h_acc[i][_TAP_OF_WEIGHT[wname]], jnp.float32)
            w_hat = _quantize_one(w, h, method, qcfg, bits)
            mses.append(float(jnp.mean((w - w_hat) ** 2)))
            p[grp][wname] = w_hat.astype(w.dtype)
        new_blocks = layer_set(new_blocks, i, p)
    out = dict(params, blocks=(new_blocks,))
    return out, QuantReport(method=method, bits=bits, layer_mse=mses)


# ---------------------------------------------------------------------------
# KV-cache codebook calibration (paged_glvq)
# ---------------------------------------------------------------------------

_ATTN_KINDS = ("attn", "attn_local", "attn_moe")


@dataclasses.dataclass
class KVCodebook:
    """Calibrated per-head GLVQ codebooks for the ``paged_glvq`` KV cache.

    ``blocks`` aligns with ``cfg.scan_unit`` (None for non-attention
    kinds); each attention entry is a dict of the ``GLVQ_BOOK_LEAVES``
    with a leading scan-repeat axis: kg/kgi/vg/vgi [R, KV, d, d],
    kmu/vmu [R, KV].  ``tail`` aligns with ``cfg.scan_tail``, same leaves
    without the repeat axis.  ``models.lm.cache_init`` grafts these over
    the identity defaults; ``serving.engine.EngineConfig.kv_codebook``
    threads them into the engine."""
    bits: int
    d: int
    hd: int
    blocks: Tuple[Optional[Dict[str, np.ndarray]], ...]
    tail: Tuple[Optional[Dict[str, np.ndarray]], ...]


def _kv_sample_cache(params, tokens, cfg: ModelConfig, chunk: int):
    """Run the dense serving step over one token batch; the filled dense
    cache IS the post-RoPE K/V tap (family-agnostic: any stack lm serves)."""
    from repro.models import lm
    b, t = tokens.shape
    cache = lm.cache_init(cfg, b, t, jnp.float32)
    if any(k == "attn_local" for k in
           tuple(cfg.scan_unit) + tuple(cfg.scan_tail)):
        chunk = min(chunk, cfg.window)    # ring layers reject wider chunks
    for start in range(0, t, chunk):
        slab = tokens[:, start:start + chunk]
        lens = jnp.full((b,), slab.shape[1], jnp.int32)
        pos = jnp.full((b,), start, jnp.int32)
        _, cache = lm.chunk_step(params, cache, jnp.asarray(slab), pos, lens,
                                 cfg, dtype=jnp.float32)
    return cache


@functools.partial(jax.jit, static_argnames=("qcfg",))
def _fit_kv_heads(samples, bits, qcfg: GLVQConfig):
    """samples [KV, n_tok, hd] (per-token max-abs normalized) -> per-head
    (g [KV, d, d], mu [KV]) via the paper's Babai-STE loop.  Rows are
    already in [-1, 1], so quantize_group's global scale is exactly 1 and
    the learned (G, mu) applies verbatim to the runtime codec's
    per-token-normalized inputs."""
    fit = lambda s: quantize_group(s, None, bits, qcfg)
    out = jax.vmap(fit)(samples)
    return out["g"], out["mu"]


def _normalize_tokens(x: np.ndarray) -> np.ndarray:
    """[n_tok, hd] -> per-token max-abs normalized (the runtime codec's
    pre-lattice view)."""
    amax = np.maximum(np.max(np.abs(x), axis=-1, keepdims=True), 1e-6)
    return (x / amax).astype(np.float32)


def _fit_book(k_all, v_all, spec, qcfg: GLVQConfig, rng,
              samples_per_head: int, per_head: bool):
    """k_all/v_all [n_tok, KV, hd] (np) -> dict of GLVQ_BOOK_LEAVES
    ([KV, d, d] / [KV]) for one layer (repeat)."""
    n_kv = k_all.shape[1]
    bits = jnp.asarray(spec.bits, jnp.int32)

    def head_samples(x_all, h):
        x = x_all[:, h] if per_head else x_all.reshape(-1, x_all.shape[-1])
        if x.shape[0] > samples_per_head:
            x = x[rng.choice(x.shape[0], samples_per_head, replace=False)]
        return _normalize_tokens(x)

    def head_mse(x, g, mu):
        """Per-head runtime-codec reconstruction MSE on the fit samples
        (x [n, heads, hd] is already per-token normalized, so the codec's
        own amax is exactly 1 and (g, mu) apply verbatim)."""
        w, a = kv_cache.glvq_quantize(x, jnp.linalg.inv(g), mu, spec)
        b = kv_cache.glvq_dequantize(w, a, g, mu, spec, jnp.float32)
        return np.asarray(jnp.mean((b - x) ** 2, axis=(0, 2)))

    leaves = {}
    for side, x_all in (("k", k_all), ("v", v_all)):
        heads = [head_samples(x_all, h) for h in
                 (range(n_kv) if per_head else [0])]
        n = min(s.shape[0] for s in heads)
        stacked = jnp.asarray(np.stack([s[:n] for s in heads]))
        g, mu = _fit_kv_heads(stacked, bits, qcfg)
        g = np.asarray(g, np.float32)
        mu = np.asarray(mu, np.float32)
        # candidate selection: quantize_group's mu floor (>= 10) forces
        # companding, which can LOSE to the plain uniform grid on light-
        # tailed heads — per head, keep whichever of (learned G, mu) and
        # (identity/hi, mu=0 -> compand bypassed) reconstructs the fit
        # samples better, so calibration never regresses the codec.
        x = jnp.moveaxis(stacked, 0, 1)               # [n, heads, hd]
        eye = np.broadcast_to(np.eye(spec.d, dtype=np.float32) / spec.hi,
                              g.shape).copy()
        mse_l = head_mse(x, jnp.asarray(g), jnp.asarray(mu))
        mse_i = head_mse(x, jnp.asarray(eye), jnp.zeros_like(jnp.asarray(mu)))
        use_i = mse_i <= mse_l
        g = np.where(use_i[:, None, None], eye, g)
        mu = np.where(use_i, np.float32(0.0), mu)
        if not per_head:                    # per-layer fallback: share
            g = np.broadcast_to(g, (n_kv,) + g.shape[1:]).copy()
            mu = np.broadcast_to(mu, (n_kv,)).copy()
        leaves[side + "g"] = g.astype(np.float32)
        leaves[side + "gi"] = np.linalg.inv(g).astype(np.float32)
        leaves[side + "mu"] = mu.astype(np.float32)
    return leaves


def calibrate_kv(params, batches: Iterable[dict], cfg: ModelConfig, *,
                 bits: int = 4, d: int = 0, chunk: int = 32,
                 samples_per_head: int = 1024, per_head: bool = True,
                 qcfg: Optional[GLVQConfig] = None,
                 seed: int = 0) -> KVCodebook:
    """Fit per-head (fallback: per-layer) KV lattice codebooks.

    Runs the dense serving step over ``batches`` (dicts with "tokens"
    [B, T]), taps every attention layer's post-RoPE K/V from the filled
    dense cache, per-token max-abs normalizes (the runtime codec's
    pre-lattice view), and fits each head's generation matrix + companding
    mu with the existing ``quantize_group`` Babai-STE loop.  ``per_head=
    False`` (or too few samples) pools heads into one per-layer codebook.
    Returns a ``KVCodebook`` ready for ``EngineConfig.kv_codebook``."""
    spec = kv_cache.default_glvq_spec(cfg.hd, bits=bits, d=d or None)
    qcfg = qcfg or GLVQConfig(d=spec.d, bits=spec.bits, iters=60)
    if qcfg.d != spec.d or qcfg.bits != spec.bits:
        qcfg = dataclasses.replace(qcfg, d=spec.d, bits=spec.bits)
    rng = np.random.default_rng(seed)

    unit_kinds = tuple(cfg.scan_unit)
    tail_kinds = tuple(cfg.scan_tail)
    # samples[(where, idx, repeat)] = list of ([n_tok, KV, hd] k, same v)
    acc: Dict[tuple, list] = {}
    for batch in batches:
        tokens = np.asarray(batch["tokens"])
        t = tokens.shape[1]
        cache = _kv_sample_cache(params, tokens, cfg, chunk)

        def harvest(kv_leaves, key, t=t):
            k, v = np.asarray(kv_leaves["k"]), np.asarray(kv_leaves["v"])
            s = min(t, k.shape[1])          # ring layers hold min(window, t)
            kk = k[:, :s].reshape(-1, k.shape[2], k.shape[3])
            vv = v[:, :s].reshape(-1, v.shape[2], v.shape[3])
            acc.setdefault(key, []).append((kk, vv))

        for ui, kind in enumerate(unit_kinds):
            if kind not in _ATTN_KINDS:
                continue
            for r in range(cfg.n_repeats):
                harvest(layer_slice(cache["blocks"][ui], r), ("u", ui, r))
        for ti, kind in enumerate(tail_kinds):
            if kind in _ATTN_KINDS:
                harvest(cache["tail"][ti], ("t", ti, 0))

    def fit(key):
        parts = acc[key]
        k_all = np.concatenate([p[0] for p in parts])
        v_all = np.concatenate([p[1] for p in parts])
        ph = per_head and k_all.shape[0] >= 4 * k_all.shape[1]
        return _fit_book(k_all, v_all, spec, qcfg, rng,
                         samples_per_head, ph)

    blocks: list = []
    for ui, kind in enumerate(unit_kinds):
        if kind not in _ATTN_KINDS:
            blocks.append(None)
            continue
        per_rep = [fit(("u", ui, r)) for r in range(cfg.n_repeats)]
        blocks.append({n: np.stack([b[n] for b in per_rep])
                       for n in kv_cache.GLVQ_BOOK_LEAVES})
    tail: list = []
    for ti, kind in enumerate(tail_kinds):
        tail.append(fit(("t", ti, 0)) if kind in _ATTN_KINDS else None)
    return KVCodebook(bits=spec.bits, d=spec.d, hd=spec.hd,
                      blocks=tuple(blocks), tail=tuple(tail))


def save_kv_codebook(path: str, book: KVCodebook) -> None:
    """Serialize a KVCodebook to one ``.npz`` (flattened leaf keys)."""
    arrs: Dict[str, np.ndarray] = {
        "meta": np.asarray([book.bits, book.d, book.hd,
                            len(book.blocks), len(book.tail)], np.int64)}
    for where, entries in (("b", book.blocks), ("t", book.tail)):
        for i, bk in enumerate(entries):
            if bk is None:
                continue
            for n, a in bk.items():
                arrs[f"{where}{i}/{n}"] = np.asarray(a, np.float32)
    np.savez(path, **arrs)


def load_kv_codebook(path: str) -> KVCodebook:
    with np.load(path) as z:
        bits, d, hd, nb, nt = (int(x) for x in z["meta"])

        def entry(where, i):
            keys = {n: z[f"{where}{i}/{n}"]
                    for n in kv_cache.GLVQ_BOOK_LEAVES
                    if f"{where}{i}/{n}" in z}
            return keys or None

        blocks = tuple(entry("b", i) for i in range(nb))
        tail = tuple(entry("t", i) for i in range(nt))
    return KVCodebook(bits=bits, d=d, hd=hd, blocks=blocks, tail=tail)


def _quantize_one(w, h, method: str, qcfg: GLVQConfig, bits: float):
    k, n = w.shape
    gs = qcfg.group_size
    if method == "glvq+":
        # beyond-paper: per-output-column RMS scale, lattice on normalized W
        cs = jnp.sqrt(jnp.mean(w.astype(jnp.float32) ** 2, axis=0,
                               keepdims=True)) + 1e-12
        wh = _quantize_one(w / cs, h, "glvq", qcfg, bits)
        return (wh * cs).astype(w.dtype)
    if method == "rtn":
        return rtn_quantize(w, int(round(bits)), gs)
    if method == "gptq":
        hh = h if h is not None else jnp.eye(k)
        return gptq_quantize(w, hh, int(round(bits)), gs)

    # lattice family -----------------------------------------------------
    cfg_l = qcfg
    if method == "fixed-lattice":
        cfg_l = dataclasses.replace(qcfg, learn_lattice=False,
                                    use_companding=False)
    if method == "gcd":
        cfg_l = dataclasses.replace(qcfg, rounding="gcd")
    n_groups = k // gs
    if method == "glvq-u" or method == "fixed-lattice" or method == "gcd" \
            or float(bits).is_integer():
        bpg = np.full(n_groups, int(round(bits)), np.int32)
    else:
        s = np.asarray(group_salience(w, h, gs))
        v = np.var(np.asarray(w).reshape(n_groups, -1), axis=1)
        bpg = fractional_bits(s, v, bits)
    if method == "glvq" and float(bits).is_integer() and cfg_l.bit_allocation:
        bpg = sdba_fn(w, h, gs, int(round(bits)))
    q = quantize_layer(w, h, cfg_l, jnp.asarray(bpg))
    return dequantize_layer(q, cfg_l)
