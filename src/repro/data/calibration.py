"""Calibration capture + whole-model PTQ drivers (dense LM family).

Mirrors GPTQ-style calibration: run the model over calibration batches and
accumulate the second moment H = X^T X of every linear layer's input, then
quantize each weight with its own H. Reuses ``repro.models.layers`` for all
math; only the layer loop is reimplemented (python-level, unstacked) because
taps inside jax.lax.scan would change the core model code.

``quantize_model`` returns FAKE-QUANT (dequantized) params — the accuracy
evaluation path of the paper's Tables 1-3. Packed serving payloads come from
``repro.core.quantized.quantize_param_tree``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sdba import group_salience, fractional_bits, sdba as sdba_fn
from repro.core.baselines import gptq_quantize, rtn_quantize, fixed_lattice_init
from repro.core.glvq import GLVQConfig, quantize_layer, dequantize_layer
from repro.models import layers
from repro.models.layers import rms_norm

__all__ = ["collect_h", "quantize_model", "layer_slice", "layer_set"]


def layer_slice(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def layer_set(tree, i: int, sub):
    return jax.tree.map(lambda a, s: a.at[i].set(s), tree, sub)


def _dense_taps(params, batch, cfg: ModelConfig, dtype=jnp.float32):
    """Forward pass emitting per-layer linear inputs (dense/vlm families)."""
    from repro.models import lm
    x, pos = lm.embed_inputs(params, batch, cfg, dtype)
    taps: List[Dict[str, jnp.ndarray]] = []
    n_rep = cfg.n_heads // cfg.n_kv_heads
    r = cfg.n_repeats
    assert cfg.scan_unit == ("attn",), "calibration taps: dense family only"
    blocks = params["blocks"][0]
    for i in range(r):
        p = layer_slice(blocks, i)
        t = {}
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        t["attn_in"] = h
        q, k, v = layers._qkv(p["attn"], h, cfg, pos)
        mask = jnp.tril(jnp.ones((h.shape[1], h.shape[1]), jnp.bool_))[None, None, None]
        o = layers._sdpa(q, k, v, mask, n_rep).reshape(h.shape[0], h.shape[1], -1)
        t["attn_mid"] = o
        x = x + o @ p["attn"]["wo"].astype(dtype)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        t["mlp_in"] = h
        m = h @ p["mlp"]["w1"].astype(dtype)
        if cfg.act == "swiglu":
            m = jax.nn.silu(m) * (h @ p["mlp"]["w3"].astype(dtype))
        elif cfg.act == "sq_relu":
            m = jnp.square(jax.nn.relu(m))
        else:
            m = jax.nn.gelu(m)
        t["mlp_mid"] = m
        x = x + m @ p["mlp"]["w2"].astype(dtype)
        taps.append(t)
    return taps

_TAP_OF_WEIGHT = dict(wq="attn_in", wk="attn_in", wv="attn_in", wo="attn_mid",
                      w1="mlp_in", w3="mlp_in", w2="mlp_mid")
_GROUP_OF_WEIGHT = dict(wq="attn", wk="attn", wv="attn", wo="attn",
                        w1="mlp", w3="mlp", w2="mlp")


def collect_h(params, batches: Iterable[dict], cfg: ModelConfig):
    """Accumulate H = X^T X per (layer, tap). Returns h[layer][tap] (np)."""
    acc: List[Dict[str, np.ndarray]] = []
    n = 0
    for batch in batches:
        taps = _dense_taps(params, batch, cfg)
        for i, t in enumerate(taps):
            if len(acc) <= i:
                acc.append({})
            for k, v in t.items():
                flat = np.asarray(v, np.float64).reshape(-1, v.shape[-1])
                h = flat.T @ flat
                acc[i][k] = acc[i].get(k, 0.0) + h
        n += 1
    return acc


@dataclasses.dataclass
class QuantReport:
    method: str
    bits: float
    layer_mse: List[float]


def quantize_model(params, cfg: ModelConfig, *, method: str = "glvq",
                   qcfg: Optional[GLVQConfig] = None,
                   h_acc: Optional[list] = None,
                   bits: Optional[float] = None):
    """Fake-quant every transformer linear; returns (new_params, report).

    method: glvq | glvq+ | glvq-u | rtn | gptq | fixed-lattice | gcd
    ("glvq+" = beyond-paper: per-output-column RMS normalization before the
    lattice, absorbing per-channel dynamic range like AWQ/RTN scales do.)
    ``bits`` may be fractional for glvq (SDBA mixes widths per Sec 4.3).
    """
    qcfg = qcfg or GLVQConfig()
    bits = bits if bits is not None else float(qcfg.bits)
    blocks = params["blocks"][0]
    r = cfg.n_repeats
    new_blocks = blocks
    mses = []
    for i in range(r):
        p = layer_slice(blocks, i)
        for grp, wname in [("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
                           ("attn", "wo"), ("mlp", "w1"), ("mlp", "w3"),
                           ("mlp", "w2")]:
            if wname not in p[grp]:
                continue
            w = p[grp][wname]
            h = None
            if h_acc is not None:
                h = jnp.asarray(h_acc[i][_TAP_OF_WEIGHT[wname]], jnp.float32)
            w_hat = _quantize_one(w, h, method, qcfg, bits)
            mses.append(float(jnp.mean((w - w_hat) ** 2)))
            p[grp][wname] = w_hat.astype(w.dtype)
        new_blocks = layer_set(new_blocks, i, p)
    out = dict(params, blocks=(new_blocks,))
    return out, QuantReport(method=method, bits=bits, layer_mse=mses)


def _quantize_one(w, h, method: str, qcfg: GLVQConfig, bits: float):
    k, n = w.shape
    gs = qcfg.group_size
    if method == "glvq+":
        # beyond-paper: per-output-column RMS scale, lattice on normalized W
        cs = jnp.sqrt(jnp.mean(w.astype(jnp.float32) ** 2, axis=0,
                               keepdims=True)) + 1e-12
        wh = _quantize_one(w / cs, h, "glvq", qcfg, bits)
        return (wh * cs).astype(w.dtype)
    if method == "rtn":
        return rtn_quantize(w, int(round(bits)), gs)
    if method == "gptq":
        hh = h if h is not None else jnp.eye(k)
        return gptq_quantize(w, hh, int(round(bits)), gs)

    # lattice family -----------------------------------------------------
    cfg_l = qcfg
    if method == "fixed-lattice":
        cfg_l = dataclasses.replace(qcfg, learn_lattice=False,
                                    use_companding=False)
    if method == "gcd":
        cfg_l = dataclasses.replace(qcfg, rounding="gcd")
    n_groups = k // gs
    if method == "glvq-u" or method == "fixed-lattice" or method == "gcd" \
            or float(bits).is_integer():
        bpg = np.full(n_groups, int(round(bits)), np.int32)
    else:
        s = np.asarray(group_salience(w, h, gs))
        v = np.var(np.asarray(w).reshape(n_groups, -1), axis=1)
        bpg = fractional_bits(s, v, bits)
    if method == "glvq" and float(bits).is_integer() and cfg_l.bit_allocation:
        bpg = sdba_fn(w, h, gs, int(round(bits)))
    q = quantize_layer(w, h, cfg_l, jnp.asarray(bpg))
    return dequantize_layer(q, cfg_l)
