"""repro: GLVQ low-bit LLM compression framework (JAX + Pallas TPU)."""
__version__ = "0.1.0"
