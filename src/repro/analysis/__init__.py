"""Correctness tooling for the serving stack.

Two layers:

* **Static lint** (``repro.analysis.lint`` + ``repro.analysis.rules``) —
  AST rules R1-R8 for the JAX bug classes that fail silently: host syncs
  in hot paths, recompile hazards, Mosaic tile violations, incomplete
  sharding rules, dtype drift, frozen-config mutation, untraced RNG.
  Run via ``python -m repro.analysis`` (or the ``repro-lint`` entry).

* **Runtime sanitizer** (``repro.analysis.runtime``) — checkify-based
  in-graph assertions plus host-side allocator/compile-counter checks,
  enabled per-engine with ``EngineConfig(debug_checks=True)``.  Off by
  default and graph-free when off.
"""
from repro.analysis.lint import (Finding, Rule, all_rules, get_rule,
                                 lint_paths, lint_source)

__all__ = ["Finding", "Rule", "all_rules", "get_rule", "lint_paths",
           "lint_source"]
