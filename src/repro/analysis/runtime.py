"""Runtime sanitizer for the serving engine (``EngineConfig.debug_checks``).

Layer 2 of the analysis subsystem: the invariants static lint cannot see
because they depend on runtime DATA — a corrupted block table, NaN logits
from a bad payload, an allocator handing one block to two slots.  Three
mechanisms:

* **In-graph checkify assertions** (``make_checked_step``): traced into
  the jitted serving step, so they check the exact tensors the compiled
  program consumes — block-table ids ``< num_blocks``, position bounds
  ``pos + take <= s_cache``, finite sampled logprobs after ``chunk_step``
  (the NaN guard).  Only built when ``debug_checks=True``; the disabled
  engine jits the raw step function, so the compiled graph is untouched
  (benchmarks/serving.py asserts this).

* **Host-side structural checks**: ``check_block_aliasing`` walks the
  ``SlotPages`` table each iteration and enforces the refcounted
  ownership invariant (owner count == refcount, live ∩ free empty, no
  live block at refcount 0) — sharing is legal exactly when the
  allocator's books agree with the tables.  ``check_payload_alignment``
  validates packed GLVQ payloads against their ``QuantLinearMeta`` once at
  engine build (shapes are static; no per-step cost).

* **RecompileMonitor**: trips when the PR-7 compile counter exceeds the
  scheduler policy's program budget — the recompile-storm detector.

Every trip raises ``DebugCheckError`` (``.check`` names the tripped
check) after counting ``serving_debug_check_failures_total{check=}`` in
the engine's metrics registry.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify

__all__ = ["DebugCheckError", "RecompileMonitor", "make_checked_step",
           "consume_error", "check_block_aliasing",
           "check_payload_alignment", "FAILURE_COUNTER"]

#: the Prometheus-visible trip counter (PR-7 metrics registry)
FAILURE_COUNTER = "serving_debug_check_failures_total"

_TAG_OPEN, _TAG_CLOSE = "[debug:", "]"


class DebugCheckError(RuntimeError):
    """A sanitizer invariant failed.  ``check`` is the short machine name
    (block_table | bounds | nan_logits | block_aliasing | recompile_storm
    | payload_alignment) — also the ``check=`` label on the counter."""

    def __init__(self, check: str, message: str):
        super().__init__(f"[debug:{check}] {message}")
        self.check = check


def _tag(check: str, message: str) -> str:
    return f"{_TAG_OPEN}{check}{_TAG_CLOSE} {message}"


def parse_failure(message: str) -> Tuple[str, str]:
    """Recover (check, message) from a tagged checkify error string."""
    i = message.find(_TAG_OPEN)
    if i < 0:
        return "unknown", message
    j = message.find(_TAG_CLOSE, i)
    if j < 0:
        return "unknown", message
    return message[i + len(_TAG_OPEN):j], message[j + 1:].strip()


# ---------------------------------------------------------------------------
# in-graph checks (checkify)
# ---------------------------------------------------------------------------

def make_checked_step(step_fn, *, s_cache: int, num_blocks: Optional[int]):
    """Wrap the scheduler's step closure with in-graph assertions and jit.

    The wrapped callable returns ``(err, (out, cache))`` — the scheduler
    surfaces ``err`` through :func:`consume_error` right after the host
    sync it already pays for the sampled ids.  ``num_blocks`` is None for
    the dense cache kind (no block table to validate).
    """

    def body(p, c, toks, poss, lens, seeds, sidx, temps, tks, tps):
        if num_blocks is not None and isinstance(c, dict) and "table" in c:
            tbl = c["table"]
            checkify.check(
                jnp.all((tbl >= 0) & (tbl < num_blocks)),
                _tag("block_table",
                     f"block-table id outside [0, {num_blocks}): the step "
                     "would gather/scatter a foreign slot's KV blocks"))
        checkify.check(
            jnp.all(lens >= 0) & jnp.all(poss >= 0)
            & jnp.all(poss + lens <= s_cache),
            _tag("bounds",
                 f"slot positions escape the cache: need 0 <= pos and "
                 f"pos + take <= s_cache ({s_cache})"))
        out, c2 = step_fn(p, c, toks, poss, lens, seeds, sidx,
                          temps, tks, tps)
        toks_out, lp, tv, ti = out
        live = lens > 0
        finite = jnp.all(jnp.where(live, jnp.isfinite(lp), True))
        if tv.ndim == 2 and tv.shape[1]:
            finite = finite & jnp.all(
                jnp.where(live[:, None], jnp.isfinite(tv), True))
        checkify.check(
            finite,
            _tag("nan_logits",
                 "non-finite logprob on a live slot after chunk_step: "
                 "NaN/Inf reached the logits (payload corruption, overflow, "
                 "or an unmasked pad lane)"))
        return out, c2

    return jax.jit(checkify.checkify(body, errors=checkify.user_checks))


def consume_error(err) -> Optional[DebugCheckError]:
    """Turn a checkify error (first failed check, if any) into a
    DebugCheckError — or None on a clean step.  Calling ``err.get()``
    syncs; debug mode accepts that."""
    msg = err.get()
    if not msg:
        return None
    check, detail = parse_failure(msg)
    return DebugCheckError(check, detail)


# ---------------------------------------------------------------------------
# host-side structural checks
# ---------------------------------------------------------------------------

def check_block_aliasing(pages) -> int:
    """Refcounted ownership invariant over the ``SlotPages`` table (the
    PR-3 exclusive-ownership check, relaxed for prefix-cache sharing):

    * a block's slot-owner count must EQUAL its allocator refcount — a
      table reference the allocator doesn't know about means a decref
      path was skipped (or an incref never happened), and the block will
      be handed out while a slot still reads it;
    * no live table reference may sit on the free list (live ∩ free = ∅);
    * no live table reference may be at refcount 0 (parked blocks are
      cache-resident but must not appear in any slot's table).

    Returns the number of distinct live blocks checked."""
    owners: dict = {}
    free = getattr(pages.alloc, "_free_set", frozenset())
    for slot in range(pages.table.shape[0]):
        n = int(pages.counts[slot])
        for b in pages.table[slot, :n]:
            b = int(b)
            if b in free:
                raise DebugCheckError(
                    "block_aliasing",
                    f"block {b} is live in slot {slot}'s table AND on the "
                    "free list: the next alloc would hand it out again")
            owners.setdefault(b, []).append(slot)
    refcount = getattr(pages.alloc, "refcount", lambda _b: 1)
    for b, slots in owners.items():
        refs = int(refcount(b))
        if refs == 0:
            raise DebugCheckError(
                "block_aliasing",
                f"block {b} is live in slot table(s) {slots} but its "
                "refcount is 0: eviction would free KV a slot still reads")
        if refs != len(slots):
            raise DebugCheckError(
                "block_aliasing",
                f"block {b} has {len(slots)} table owner(s) {slots} but "
                f"refcount {refs}: a missed incref/decref will leak the "
                "block or free it under a live reader")
    return len(owners)


def check_payload_alignment(params, qmeta) -> int:
    """Packed GLVQ payloads must agree with their ``QuantLinearMeta``:
    ``packed`` is uint32 [lead..., K, n_words].  A mismatched word count
    mis-strides every decode; wrong dtype breaks the bit unpack.  Static
    shapes — runs once at engine build.  Returns payloads checked."""
    if not qmeta:
        return 0
    checked = 0

    def walk(node, names):
        nonlocal checked
        if isinstance(node, dict):
            if "packed" in node and "scale" in node:
                key = tuple(names[-2:])
                meta = qmeta.get(key) if hasattr(qmeta, "get") else None
                packed = node["packed"]
                if str(packed.dtype) != "uint32":
                    raise DebugCheckError(
                        "payload_alignment",
                        f"payload {key}: packed dtype {packed.dtype}, "
                        "expected uint32 (bit-unpack reads 32-bit words)")
                if meta is not None:
                    k, words = packed.shape[-2], packed.shape[-1]
                    if words != meta.n_words or k != meta.k:
                        raise DebugCheckError(
                            "payload_alignment",
                            f"payload {key}: packed [..., {k}, {words}] "
                            f"vs meta (k={meta.k}, n_words={meta.n_words})"
                            " — decode would mis-stride every group")
                checked += 1
                return
            for name, v in node.items():
                walk(v, names + (name,))
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v, names)

    walk(params, ())
    return checked


# ---------------------------------------------------------------------------
# recompile-storm detector
# ---------------------------------------------------------------------------

class RecompileMonitor:
    """Trips when the compile-event counter (PR 7: one bump per traced
    slab program) exceeds the policy's program budget.  A healthy engine
    compiles one program per policy rung and then never again; unstable
    input signatures (weak types, drifting shapes, non-hashable statics)
    show up here as compiles growing with iterations."""

    def __init__(self, max_programs: int):
        self.max_programs = max(1, int(max_programs))

    def observe(self, compiles: int, iterations: int):
        if compiles > self.max_programs:
            raise DebugCheckError(
                "recompile_storm",
                f"{compiles} step programs compiled in {iterations} "
                f"iterations, over the policy budget of "
                f"{self.max_programs}: the step input signature is "
                "unstable (shape/dtype drift or non-hashable statics)")
