"""Rule framework for the repo's JAX-aware static lint suite.

The serving stack's worst bugs are silent: a host sync inside the decode
loop, a jitted body closing over mutable state (recompile storm), a Pallas
``BlockSpec`` whose index_map arity drifts from its grid, a sharding rule
bound to a weight name that no config produces.  Each of those is a *rule*
here (``repro.analysis.rules``); this module owns the machinery:

  * ``Rule`` — an AST-visitor check over one file (``check``) or a
    whole-project semantic check (``check_project``), with a per-rule
    ``scope`` (path prefixes) and ``allow`` list.
  * Allowlists — ``{(path, symbol): (count, reason)}``: up to ``count``
    findings of ``symbol`` in ``path`` are sanctioned (``None`` = any
    number).  Growth beyond the cap FAILS — the same pinned-count semantics
    ``scripts/lint_timing.py`` used; every entry carries a human reason.
  * Baseline — a checked-in text file of tolerated finding keys
    (``rule|path|symbol`` with a count), so the suite can land on a codebase
    with known debt and still gate NEW violations.  This repo ships an empty
    baseline for ``src/repro``: every real finding was fixed or explicitly
    allowlisted with a reason.

CLI: ``python -m repro.analysis`` (see ``__main__``).  Exit 0 = clean,
1 = violations, 2 = usage error — the same contract the old timing lint had
so ``scripts/ci.sh`` gates on it directly.
"""
from __future__ import annotations

import ast
import dataclasses
from collections import Counter
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "Rule", "register_rule", "all_rules", "get_rule",
           "lint_file", "lint_source", "lint_paths", "apply_allowlist",
           "load_baseline", "write_baseline", "apply_baseline", "repo_root"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation.  ``symbol`` is the stable machine tag (what the
    allowlist and baseline key on — line numbers drift, symbols don't)."""
    rule: str
    path: str                   # posix path relative to the scanned root
    line: int
    symbol: str
    message: str

    @property
    def baseline_key(self) -> str:
        return f"{self.rule}|{self.path}|{self.symbol}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Rule:
    """One lint rule.  Subclasses override ``check`` (per-file AST) and/or
    ``check_project`` (whole-repo semantic checks that need imports)."""

    name: str = ""
    title: str = ""
    # path prefixes (relative to the scanned root) this rule applies to;
    # empty = every file
    scope: Tuple[str, ...] = ()
    # path prefixes (or exact rel paths) the rule never touches
    exclude: Tuple[str, ...] = ()
    # {(path, symbol): (max_count | None, reason)} — symbol "" matches any
    allow: Dict[Tuple[str, str], Tuple[Optional[int], str]] = {}

    def applies(self, rel: str) -> bool:
        if any(rel.startswith(p) for p in self.exclude):
            return False
        return not self.scope or any(rel.startswith(p) for p in self.scope)

    def check(self, rel: str, tree: ast.AST, text: str) -> List[Finding]:
        return []

    def check_project(self, root: Path) -> List[Finding]:
        return []

    # -- helpers -------------------------------------------------------------
    def finding(self, rel: str, node: ast.AST, symbol: str,
                message: str) -> Finding:
        return Finding(rule=self.name, path=rel,
                       line=getattr(node, "lineno", 0), symbol=symbol,
                       message=message)


_RULES: Dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate + register a rule by its ``name``."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    _RULES[rule.name] = rule
    return cls


def all_rules() -> Tuple[Rule, ...]:
    from repro.analysis import rules as _  # noqa: F401  (registers on import)
    return tuple(_RULES[k] for k in sorted(_RULES))


def get_rule(name: str) -> Rule:
    from repro.analysis import rules as _  # noqa: F401
    if name not in _RULES:
        raise KeyError(f"unknown rule {name!r}; available: {sorted(_RULES)}")
    return _RULES[name]


# ---------------------------------------------------------------------------
# Allowlist semantics (pinned counts, lint_timing-style)
# ---------------------------------------------------------------------------

def _allow_entry(rule: Rule, path: str, symbol: str):
    """Match ``allow`` keys by exact rel path or path suffix (so the same
    table works whether the scan root is ``src/repro`` or a parent dir)."""
    for (p, s), v in rule.allow.items():
        if s not in ("", symbol):
            continue
        if path == p or path.endswith("/" + p):
            return v
    return None


def apply_allowlist(rule: Rule, findings: Sequence[Finding]) -> List[Finding]:
    """Suppress up to the allowed count per (path, symbol); everything past
    the cap is reported with the cap + reason attached."""
    out: List[Finding] = []
    groups: Dict[Tuple[str, str], List[Finding]] = {}
    for f in findings:
        groups.setdefault((f.path, f.symbol), []).append(f)
    for (path, symbol), fs in groups.items():
        entry = _allow_entry(rule, path, symbol)
        if entry is None:
            out.extend(fs)
            continue
        cap, reason = entry
        if cap is None or len(fs) <= cap:
            continue                       # within the pinned budget
        for f in fs:
            out.append(dataclasses.replace(
                f, message=f"{f.message} — {len(fs)} found, {cap} allowed "
                           f"({reason})"))
    return out


# ---------------------------------------------------------------------------
# Baseline (checked-in tolerated-findings file; ships empty)
# ---------------------------------------------------------------------------

def load_baseline(path: Path) -> Counter:
    """Baseline line format: ``<count> <rule>|<path>|<symbol>``; ``#``
    comments and blank lines ignored."""
    counts: Counter = Counter()
    if not Path(path).exists():
        return counts
    for raw in Path(path).read_text(encoding="utf-8").splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        n, _, key = line.partition(" ")
        counts[key.strip()] += int(n)
    return counts


def write_baseline(findings: Sequence[Finding], path: Path):
    counts = Counter(f.baseline_key for f in findings)
    lines = ["# repro.analysis baseline — tolerated findings, one",
             "# '<count> <rule>|<path>|<symbol>' per line.  Regenerate with:",
             "#   python -m repro.analysis --write-baseline",
             "# An empty baseline means src/repro is lint-clean."]
    for key in sorted(counts):
        lines.append(f"{counts[key]} {key}")
    Path(path).write_text("\n".join(lines) + "\n", encoding="utf-8")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Counter) -> Tuple[List[Finding], Counter]:
    """Subtract baselined findings; returns (new findings, stale entries —
    baseline debt that no longer exists and should be dropped)."""
    budget = Counter(baseline)
    fresh: List[Finding] = []
    for f in findings:
        if budget[f.baseline_key] > 0:
            budget[f.baseline_key] -= 1
        else:
            fresh.append(f)
    stale = Counter({k: v for k, v in budget.items() if v > 0})
    return fresh, stale


# ---------------------------------------------------------------------------
# Runners
# ---------------------------------------------------------------------------

def lint_source(rule: Rule, rel: str, text: str,
                allowlist: bool = True) -> List[Finding]:
    """Run ONE rule over one source string (the unit-test entry point)."""
    if not rule.applies(rel):
        return []
    tree = ast.parse(text)
    found = rule.check(rel, tree, text)
    return apply_allowlist(rule, found) if allowlist else list(found)


def lint_file(path: Path, rel: str, rules: Sequence[Rule]) -> List[Finding]:
    text = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(rule="parse", path=rel, line=e.lineno or 0,
                        symbol="syntax-error", message=f"unparseable: {e}")]
    out: List[Finding] = []
    for rule in rules:
        if rule.applies(rel):
            out.extend(apply_allowlist(rule, rule.check(rel, tree, text)))
    return out


def lint_paths(paths: Iterable[Path], rules: Sequence[Rule], *,
               project_checks: bool = True) -> List[Finding]:
    """Lint every ``*.py`` under each path (files are scanned relative to
    the given root so rule scopes like ``serving/`` match)."""
    out: List[Finding] = []
    for root in paths:
        root = Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for f in files:
            rel = f.relative_to(base).as_posix()
            out.extend(lint_file(f, rel, rules))
        if project_checks and root.is_dir():
            for rule in rules:
                out.extend(apply_allowlist(rule, rule.check_project(root)))
    return out


def repo_root() -> Path:
    """The repo checkout root (this file lives at src/repro/analysis/)."""
    return Path(__file__).resolve().parents[3]
