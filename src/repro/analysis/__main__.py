"""CLI: ``python -m repro.analysis [paths...]`` (also the ``repro-lint``
console entry).

Exit codes match the old ``scripts/lint_timing.py`` contract so
``scripts/ci.sh`` gates on it unchanged: 0 clean, 1 violations, 2 usage.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import lint


def main(argv=None) -> int:
    root = lint.repo_root()
    default_baseline = Path(__file__).resolve().parent / "baseline.txt"
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static lint for the repro serving stack "
                    "(rules R1-R8; see repro.analysis.rules)")
    ap.add_argument("paths", nargs="*", type=Path,
                    default=[root / "src" / "repro"],
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--baseline", type=Path, default=default_baseline,
                    help="tolerated-findings file (default: the checked-in "
                         "src/repro/analysis/baseline.txt, which is empty)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as the new baseline "
                         "instead of failing on them")
    ap.add_argument("--rules", default="",
                    help="comma-separated subset, e.g. R1,R4 (default: all)")
    ap.add_argument("--no-project-checks", action="store_true",
                    help="skip whole-project semantic checks (R5 config "
                         "loading); AST rules only")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = lint.all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.name}  {r.title}")
        return 0
    if args.rules:
        want = {s.strip() for s in args.rules.split(",") if s.strip()}
        unknown = want - {r.name for r in rules}
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = tuple(r for r in rules if r.name in want)
    for p in args.paths:
        if not Path(p).exists():
            print(f"no such path: {p}", file=sys.stderr)
            return 2

    findings = lint.lint_paths(args.paths, rules,
                               project_checks=not args.no_project_checks)
    if args.write_baseline:
        lint.write_baseline(findings, args.baseline)
        print(f"[repro-lint] wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    fresh, stale = lint.apply_baseline(
        findings, lint.load_baseline(args.baseline))
    for f in sorted(fresh, key=lambda f: (f.path, f.line, f.rule)):
        print(f.format())
    for key, n in sorted(stale.items()):
        print(f"[repro-lint] note: baseline entry {key!r} x{n} no longer "
              "matches anything — debt paid down, remove it")
    if fresh:
        print(f"[repro-lint] {len(fresh)} violation(s) "
              f"(baseline: {args.baseline})")
        return 1
    print(f"[repro-lint] clean: {len(rules)} rule(s) over "
          f"{', '.join(str(p) for p in args.paths)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
