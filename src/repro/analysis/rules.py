"""The repo-specific lint rules (R1–R8).

Every rule targets a bug class that is *silent* in JAX: nothing crashes,
the serving loop just gets slower (host syncs, recompile storms), subtly
wrong (float64 drift, frozen-config mutation), or falls over only on real
TPUs (Mosaic tile constraints).  Deliberate exceptions live in each rule's
``allow`` table with a pinned count and a reason — growth past the pin
fails CI, exactly like the old ``scripts/lint_timing.py`` contract.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.lint import Finding, Rule, register_rule

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

_JIT_NAMES = {"jax.jit", "jit", "jax.pmap", "pmap"}
_PARTIAL_NAMES = {"functools.partial", "partial"}

#: mesh axis names every PartitionSpec in this repo may legally reference
#: (see repro.launch.mesh: ("pod", "data", "model") / ("data", "model")).
MESH_AXES = ("pod", "data", "model")


def dotted(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _int_const(node) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _int_const(node.operand)
        return -v if v is not None else None
    return None


def _nondefault_params(fn) -> Set[str]:
    """Positional params WITHOUT defaults — in this codebase those are the
    traced arguments; statics ride in as kw-only / defaulted captures
    (``lambda i, tbl, _nd=nd: ...``)."""
    args = fn.args
    pos = list(args.posonlyargs) + list(args.args)
    n_def = len(args.defaults)
    names = {a.arg for a in (pos[:-n_def] if n_def else pos)}
    names.discard("self")
    names.discard("cls")
    return names


def _static_argnames(deco: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in deco.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
    return out


class _TracedFunctions(ast.NodeVisitor):
    """Find every function whose body JAX traces: jit-decorated defs,
    defs wrapped at a ``jax.jit(f)`` call site, lambdas inside jit calls,
    Pallas kernel bodies (first arg of ``pallas_call`` / ``*_kernel``
    naming convention)."""

    def __init__(self):
        self.defs: Dict[str, List[ast.AST]] = {}
        self.traced: List[Tuple[ast.AST, Set[str]]] = []  # (fn, static names)
        self._wrapped: List[Tuple[str, Set[str]]] = []

    def _record_def(self, node):
        self.defs.setdefault(node.name, []).append(node)
        statics: Optional[Set[str]] = None
        for deco in node.decorator_list:
            d = dotted(deco if not isinstance(deco, ast.Call) else deco.func)
            if d in _JIT_NAMES:
                statics = _static_argnames(deco) \
                    if isinstance(deco, ast.Call) else set()
            elif isinstance(deco, ast.Call) and d in _PARTIAL_NAMES \
                    and deco.args and dotted(deco.args[0]) in _JIT_NAMES:
                statics = _static_argnames(deco)
        if statics is not None:
            self.traced.append((node, statics))
        elif node.name.endswith("_kernel"):
            self.traced.append((node, set()))

    def visit_FunctionDef(self, node):
        self._record_def(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        d = dotted(node.func)
        if d in _JIT_NAMES or d.endswith("pallas_call"):
            statics = _static_argnames(node) if d in _JIT_NAMES else set()
            if node.args:
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    self.traced.append((target, statics))
                elif isinstance(target, ast.Name):
                    self._wrapped.append((target.id, statics))
        self.generic_visit(node)

    def resolve(self, tree: ast.AST) -> List[Tuple[ast.AST, Set[str]]]:
        self.visit(tree)
        seen = {id(fn) for fn, _ in self.traced}
        for name, statics in self._wrapped:
            for fn in self.defs.get(name, []):
                if id(fn) not in seen:
                    self.traced.append((fn, statics))
                    seen.add(id(fn))
        return self.traced


def traced_functions(tree: ast.AST) -> List[Tuple[ast.AST, Set[str]]]:
    return _TracedFunctions().resolve(tree)


def _body_nodes(fn) -> List[ast.AST]:
    """All nodes in a traced body, nested defs included (they trace too)."""
    if isinstance(fn, ast.Lambda):
        return list(ast.walk(fn.body))
    out: List[ast.AST] = []
    for stmt in fn.body:
        out.extend(ast.walk(stmt))
    return out


# ---------------------------------------------------------------------------
# R1 — timing/logging hygiene (migrated from scripts/lint_timing.py)
# ---------------------------------------------------------------------------

@register_rule
class R1TimingLint(Rule):
    name = "R1"
    title = "no bare print()/time.time() — use repro.serving.metrics"
    # metrics/trace ARE the sanctioned implementations; the analysis CLI's
    # job is printing its report
    exclude = ("serving/metrics.py", "serving/trace.py", "analysis/")
    # pinned counts carried over verbatim from scripts/lint_timing.py:
    # launch drivers print their human-facing reports; ckpt manifests stamp
    # a wall-clock save time.  Anything beyond these counts fails.
    allow = {
        ("launch/roofline.py", "print"):
            (2, "roofline report is a human-facing CLI table"),
        ("launch/dryrun.py", "print"):
            (1, "dry-run summary line for operators"),
        ("launch/serve.py", "print"):
            (7, "serve demo CLI: banner + streamed token echo"),
        ("ckpt/manager.py", "time.time"):
            (1, "manifest save timestamp, not a measurement"),
    }

    def check(self, rel, tree, text):
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                out.append(self.finding(
                    rel, node, "print",
                    "bare print(): route through log_event/Timer "
                    "(repro.serving.metrics)"))
            elif dotted(node.func) == "time.time":
                out.append(self.finding(
                    rel, node, "time.time",
                    "bare time.time(): use Timer (repro.serving.metrics) "
                    "so measurements land in the registry"))
        return out


# ---------------------------------------------------------------------------
# R2 — host-sync hazards in the serving/kernel hot paths
# ---------------------------------------------------------------------------

@register_rule
class R2HostSync(Rule):
    name = "R2"
    title = "host syncs in hot paths (.item/.tolist/np.asarray/device_get)"
    scope = ("serving/", "kernels/")
    allow = {
        ("serving/scheduler.py", "np.asarray"):
            (1, "the ONE sanctioned device->host boundary per iteration: "
                "sampled ids + logprobs come back as a single batch"),
        ("serving/kvcache.py", ".tolist"):
            (1, "frees block ids from the HOST numpy table mirror — no "
                "device array involved"),
        ("kernels/ops.py", "np.asarray"):
            (2, "trace-time static gather-index build from host ints; "
                "never sees a device array"),
    }

    def check(self, rel, tree, text):
        out = []
        traced = traced_functions(tree)
        traced_nodes = {id(n) for fn, _ in traced for n in _body_nodes(fn)}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist") \
                    and not node.args:
                out.append(self.finding(
                    rel, node, f".{node.func.attr}",
                    f".{node.func.attr}() forces a device sync; keep "
                    "results on device or batch the transfer"))
            elif d in ("np.asarray", "numpy.asarray", "jax.device_get"):
                sym = "np.asarray" if d.endswith("asarray") else d
                out.append(self.finding(
                    rel, node, sym,
                    f"{d}() on a device value blocks the dispatch "
                    "pipeline; hot paths must stay async"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and id(node) in traced_nodes and len(node.args) == 1:
                out.append(self.finding(
                    rel, node, f"host-{node.func.id}",
                    f"{node.func.id}() inside a traced body concretizes a "
                    "tracer (ConcretizationError on abstract values, host "
                    "sync otherwise)"))
        return out


# ---------------------------------------------------------------------------
# R3 — recompile hazards
# ---------------------------------------------------------------------------

@register_rule
class R3Recompile(Rule):
    name = "R3"
    title = "recompile hazards in jitted bodies"
    allow = {
        ("serving/scheduler.py", "mutable-closure"):
            (1, "deliberate compile-event hook: self._compiles increments "
                "at trace time only, one bump per compiled slab shape"),
    }

    def check(self, rel, tree, text):
        out = []
        for fn, statics in traced_functions(tree):
            params = _nondefault_params(fn) - statics \
                if not isinstance(fn, ast.Lambda) else set()
            for node in _body_nodes(fn):
                # (a) writes to closed-over mutable state: every re-trace
                # repeats the side effect, and the write never lands in the
                # compiled program — classic recompile-storm smell
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    tgts = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in tgts:
                        if isinstance(t, ast.Attribute) \
                                and dotted(t).startswith("self."):
                            out.append(self.finding(
                                rel, node, "mutable-closure",
                                f"jitted body writes {dotted(t)}: traced "
                                "functions must be pure (side effect runs "
                                "only at trace time)"))
                elif isinstance(node, (ast.Global, ast.Nonlocal)):
                    out.append(self.finding(
                        rel, node, "mutable-closure",
                        "global/nonlocal write inside a jitted body"))
                # (b) Python branching on a traced argument value — forces
                # concretization; branch on .shape/.ndim/.dtype instead
                elif isinstance(node, (ast.If, ast.While)):
                    for leaf in ast.walk(node.test):
                        if isinstance(leaf, ast.Name) and leaf.id in params \
                                and not self._shape_context(node.test, leaf):
                            out.append(self.finding(
                                rel, node, "traced-branch",
                                f"Python if/while on traced arg "
                                f"{leaf.id!r}: use jnp.where/lax.cond "
                                "(shapes/dtypes are fine to branch on)"))
                            break
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d in _JIT_NAMES and self._in_loop(tree, node):
                out.append(self.finding(
                    rel, node, "jit-in-loop",
                    "jax.jit() inside a loop builds a fresh cache entry "
                    "per iteration; hoist the wrap"))
        # (c) mutable default on a static arg: unhashable -> every call
        # misses the jit cache
        for fn, statics in traced_functions(tree):
            if isinstance(fn, ast.Lambda):
                continue
            args = fn.args
            pos = list(args.posonlyargs) + list(args.args)
            defaults = list(args.defaults)
            for a, dflt in zip(pos[len(pos) - len(defaults):], defaults):
                if a.arg in statics and isinstance(
                        dflt, (ast.List, ast.Dict, ast.Set)):
                    out.append(self.finding(
                        rel, fn, "nonhashable-static",
                        f"static arg {a.arg!r} defaults to a mutable "
                        "literal: unhashable, so the jit cache never hits"))
            for a, dflt in zip(args.kwonlyargs, args.kw_defaults):
                if a.arg in statics and isinstance(
                        dflt, (ast.List, ast.Dict, ast.Set)):
                    out.append(self.finding(
                        rel, fn, "nonhashable-static",
                        f"static arg {a.arg!r} defaults to a mutable "
                        "literal: unhashable, so the jit cache never hits"))
        return out

    @staticmethod
    def _shape_context(test: ast.AST, leaf: ast.Name) -> bool:
        """True if the param only appears under .shape/.ndim/.dtype/.size
        (static metadata — branching on it is fine)."""
        for node in ast.walk(test):
            if isinstance(node, ast.Attribute) \
                    and node.attr in ("shape", "ndim", "dtype", "size"):
                if any(n is leaf for n in ast.walk(node.value)):
                    return True
        return False

    @staticmethod
    def _in_loop(tree: ast.AST, target: ast.Call) -> bool:
        for node in ast.walk(tree):
            if isinstance(node, (ast.For, ast.While)):
                for inner in ast.walk(node):
                    if inner is target:
                        return True
        return False


# ---------------------------------------------------------------------------
# R4 — Pallas tile / grid-spec lint
# ---------------------------------------------------------------------------

_SUBLANE, _LANE = 8, 128     # f32 Mosaic tile quantum (second-minor, minor)


@register_rule
class R4PallasTiles(Rule):
    name = "R4"
    title = "Pallas BlockSpec/grid/scratch consistency"
    scope = ("kernels/",)

    def check(self, rel, tree, text):
        out = []
        assigns = self._simple_assigns(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d.endswith("PrefetchScalarGridSpec"):
                out.extend(self._check_gridspec(rel, node, assigns,
                                                prefetched=True))
            elif d.endswith("pallas_call"):
                out.extend(self._check_gridspec(rel, node, assigns,
                                                prefetched=False))
            elif d.endswith("VMEM") and node.args:
                out.extend(self._check_scratch(rel, node))
        return out

    @staticmethod
    def _simple_assigns(tree) -> Dict[str, ast.AST]:
        """name -> value for single-target ``name = <tuple/list literal>``
        (used to resolve ``grid=grid`` / ``in_specs=specs`` indirections)."""
        out: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, (ast.Tuple, ast.List)):
                out[node.targets[0].id] = node.value
        return out

    def _check_gridspec(self, rel, call: ast.Call, assigns, *, prefetched):
        out = []
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        grid = kw.get("grid")
        if isinstance(grid, ast.Name):
            grid = assigns.get(grid.id)
        if not isinstance(grid, (ast.Tuple, ast.List)):
            return out                      # grid rank not statically known
        rank = len(grid.elts)
        n_prefetch = _int_const(kw.get("num_scalar_prefetch")) or 0 \
            if prefetched else 0
        expect = rank + n_prefetch
        specs = []
        for key in ("in_specs", "out_specs"):
            v = kw.get(key)
            if isinstance(v, ast.Name):
                v = assigns.get(v.id)
            if isinstance(v, (ast.Tuple, ast.List)):
                specs.extend(v.elts)
            elif v is not None:
                specs.append(v)
        for spec in specs:
            if not (isinstance(spec, ast.Call)
                    and dotted(spec.func).endswith("BlockSpec")):
                continue
            out.extend(self._check_blockspec(rel, spec, expect))
        return out

    def _check_blockspec(self, rel, spec: ast.Call, expect_arity: int):
        out = []
        shape = spec.args[0] if spec.args else None
        imap = spec.args[1] if len(spec.args) > 1 else None
        for k in spec.keywords:
            if k.arg == "index_map":
                imap = k.value
            elif k.arg in ("block_shape", "shape"):
                shape = k.value
        if isinstance(imap, ast.Lambda):
            args = imap.args
            pos = list(args.posonlyargs) + list(args.args)
            arity = len(pos) - len(args.defaults)  # defaults = static capture
            if arity != expect_arity:
                out.append(self.finding(
                    rel, spec, "index-map-arity",
                    f"index_map takes {arity} grid args but the grid spec "
                    f"provides {expect_arity} (grid rank + scalar-prefetch "
                    "operands); Mosaic will mis-slice"))
        if isinstance(shape, (ast.Tuple, ast.List)) and len(shape.elts) >= 2:
            minor = _int_const(shape.elts[-1])
            sub = _int_const(shape.elts[-2])
            if minor is not None and minor >= _LANE and minor % _LANE:
                out.append(self.finding(
                    rel, spec, "tile-shape",
                    f"block minor dim {minor} is not a multiple of "
                    f"{_LANE} (f32 lane tile); Mosaic pads or rejects"))
            if sub is not None and sub >= _SUBLANE and sub % _SUBLANE:
                out.append(self.finding(
                    rel, spec, "tile-shape",
                    f"block sublane dim {sub} is not a multiple of "
                    f"{_SUBLANE} (f32 sublane tile)"))
        return out

    def _check_scratch(self, rel, call: ast.Call):
        out = []
        shape = call.args[0]
        if isinstance(shape, (ast.Tuple, ast.List)):
            if not shape.elts:
                out.append(self.finding(
                    rel, call, "scratch-shape",
                    "0-d VMEM scratch: allocate at least (1, 1)"))
            for el in shape.elts:
                v = _int_const(el)
                if v is not None and v <= 0:
                    out.append(self.finding(
                        rel, call, "scratch-shape",
                        f"VMEM scratch dim {v} <= 0"))
            minor = _int_const(shape.elts[-1]) if shape.elts else None
            if minor is not None and minor >= _LANE and minor % _LANE:
                out.append(self.finding(
                    rel, call, "scratch-shape",
                    f"VMEM scratch minor dim {minor} not a multiple of "
                    f"{_LANE}; wastes a partial lane tile"))
        return out


# ---------------------------------------------------------------------------
# R5 — sharding completeness
# ---------------------------------------------------------------------------

@register_rule
class R5Sharding(Rule):
    name = "R5"
    title = "PartitionSpec axes exist; sharding rule names resolve"

    #: payload leaf names only quantized trees contain — classified by the
    #: dedicated payload path in parallel/sharding.py, so not "dead" even
    #: though plain param trees never produce them
    PAYLOAD_NAMES = frozenset({"packed", "g", "mu", "scale", "bits"})
    SPECIAL_NAMES = frozenset({"embed", "head", "conv"})

    def check(self, rel, tree, text):
        """Per-file half: every string literal inside a PartitionSpec
        constructor must name a real mesh axis."""
        out = []
        aliases = self._spec_aliases(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            base = dotted(node.func)
            if not (base in aliases or base.endswith("PartitionSpec")):
                continue
            for arg in node.args:
                for leaf in ast.walk(arg):
                    if isinstance(leaf, ast.Constant) \
                            and isinstance(leaf.value, str) \
                            and leaf.value not in MESH_AXES:
                        out.append(self.finding(
                            rel, node, "unknown-axis",
                            f"PartitionSpec axis {leaf.value!r} is not a "
                            f"mesh axis {MESH_AXES}; GSPMD raises at "
                            "sharding time, not at build time"))
        return out

    @staticmethod
    def _spec_aliases(tree) -> Set[str]:
        """Local names PartitionSpec was imported as (P, _P, ...)."""
        out: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom):
                for a in node.names:
                    if a.name == "PartitionSpec":
                        out.add(a.asname or a.name)
        return out

    def check_project(self, root):
        """Semantic half: load every config's param tree and verify the
        sharding rule tables cover it — and contain no dead names."""
        if not (root / "parallel" / "sharding.py").exists():
            return []                      # not scanning the real package
        try:
            from repro.configs import ARCHS, get_config, reduced
            from repro.models import registry
            from repro.parallel import sharding
            import jax
        except Exception as e:                      # pragma: no cover
            return [Finding(self.name, "parallel/sharding.py", 0,
                            "import-error",
                            f"cannot import repro for semantic check: {e}")]
        classified = (set(sharding._COL_PARALLEL)
                      | set(sharding._ROW_PARALLEL)
                      | set(sharding._REPLICATED_1D)
                      | self.PAYLOAD_NAMES | self.SPECIAL_NAMES)
        seen: Set[str] = set()
        out: List[Finding] = []
        for arch in sorted(ARCHS):
            cfg = reduced(get_config(arch))
            shapes = registry.param_shapes(cfg)
            leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
            for path, leaf in leaves:
                name = str(getattr(path[-1], "key", path[-1]))
                seen.add(name)
                if name not in classified and getattr(leaf, "ndim", 0) >= 2:
                    out.append(Finding(
                        self.name, "parallel/sharding.py", 0,
                        "unsharded-leaf",
                        f"param leaf {name!r} ({arch}, ndim="
                        f"{leaf.ndim}) matches no sharding rule: it "
                        "replicates silently and eats HBM at TP>1"))
        for name in sorted(set(sharding._COL_PARALLEL)
                           | set(sharding._ROW_PARALLEL)):
            if name not in seen:
                out.append(Finding(
                    self.name, "parallel/sharding.py", 0, "dead-rule-name",
                    f"sharding rule binds weight name {name!r} but no "
                    "config's param tree produces it (stale rule)"))
        return out


# ---------------------------------------------------------------------------
# R6 — dtype hygiene
# ---------------------------------------------------------------------------

@register_rule
class R6DtypeHygiene(Rule):
    name = "R6"
    title = "no float64 / builtin-float dtypes in hot-path code"
    # offline calibration and lattice construction legitimately use f64;
    # the serving/kernel/model hot path must not
    scope = ("kernels/", "models/", "serving/")

    _BAD_DOTTED = {"np.float64", "numpy.float64", "jnp.float64",
                   "np.double", "jnp.double"}
    _BAD_STR = {"float64", "f8", "double"}

    def check(self, rel, tree, text):
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) \
                    and dotted(node) in self._BAD_DOTTED:
                out.append(self.finding(
                    rel, node, "float64",
                    f"{dotted(node)} in hot-path code: JAX defaults to "
                    "f32; f64 silently doubles bytes and falls off the "
                    "fast path (enable_x64 is off)"))
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    # dotted float64 values are caught by the Attribute
                    # walk above; here only the spellings it can't see
                    if kw.arg == "dtype" and self._is_bad(kw.value) \
                            and not isinstance(kw.value, ast.Attribute):
                        out.append(self.finding(
                            rel, node, "float64",
                            "dtype=float/'float64' requests f64; spell "
                            "the width explicitly (jnp.float32)"))
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "astype" and node.args \
                        and self._is_bad(node.args[0]):
                    out.append(self.finding(
                        rel, node, "float64",
                        ".astype(float) upcasts to f64 under x64 and is "
                        "ambiguous without it; use an explicit dtype"))
        return out

    def _is_bad(self, node) -> bool:
        if isinstance(node, ast.Name) and node.id == "float":
            return True
        if isinstance(node, ast.Constant) and node.value in self._BAD_STR:
            return True
        return dotted(node) in self._BAD_DOTTED


# ---------------------------------------------------------------------------
# R7 — frozen-EngineConfig mutation
# ---------------------------------------------------------------------------

@register_rule
class R7FrozenConfig(Rule):
    name = "R7"
    title = "no mutation of frozen configs (EngineConfig et al.)"
    allow = {
        ("serving/engine.py", "object.__setattr__"):
            (1, "EngineConfig.__post_init__ canonicalizes stop_tokens to a "
                "tuple — the one sanctioned frozen-dataclass write"),
        ("serving/sampling.py", "object.__setattr__"):
            (1, "SamplingParams.__post_init__ normalization, same pattern"),
    }

    def check(self, rel, tree, text):
        out = []
        cfg_vars = self._engineconfig_vars(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and dotted(node.func) == "object.__setattr__":
                out.append(self.finding(
                    rel, node, "object.__setattr__",
                    "object.__setattr__ defeats frozen dataclasses; "
                    "outside __post_init__ use .replace()"))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "setattr" and node.args \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in cfg_vars:
                out.append(self.finding(
                    rel, node, "config-mutation",
                    f"setattr on EngineConfig {node.args[0].id!r}; use "
                    ".replace() — the engine caches geometry off it"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in tgts:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id in cfg_vars:
                        out.append(self.finding(
                            rel, node, "config-mutation",
                            f"assigning {dotted(t)}: EngineConfig is "
                            "frozen; use .replace() to derive a new one"))
        return out

    @staticmethod
    def _engineconfig_vars(tree) -> Set[str]:
        """Names bound to EngineConfig(...) or annotated EngineConfig."""
        out: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and dotted(node.value.func).split(".")[-1] \
                    == "EngineConfig":
                out.add(node.targets[0].id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for a in (list(node.args.posonlyargs) + list(node.args.args)
                          + list(node.args.kwonlyargs)):
                    if a.annotation is not None and "EngineConfig" in \
                            ast.dump(a.annotation):
                        out.add(a.arg)
        return out


# ---------------------------------------------------------------------------
# R8 — untraced randomness outside data/
# ---------------------------------------------------------------------------

@register_rule
class R8UntracedRandom(Rule):
    name = "R8"
    title = "np.random/random outside data/: untraced, breaks replay"
    exclude = ("data/",)
    allow = {
        ("launch/serve.py", "np.random"):
            (1, "seeded demo-prompt generator; host-side, runs once before "
                "serving starts — sampling itself is in-graph"),
    }

    def check(self, rel, tree, text):
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d.startswith(("np.random.", "numpy.random.")):
                out.append(self.finding(
                    rel, node, "np.random",
                    f"{d}(): host-side RNG is invisible to jit and breaks "
                    "seeded replay; thread a jax.random key (or move it "
                    "to data/)"))
            elif d.startswith("random.") and self._imports_random(tree):
                out.append(self.finding(
                    rel, node, "random",
                    f"{d}(): stdlib RNG shares global state across "
                    "requests; use jax.random with a per-request seed"))
        return out

    @staticmethod
    def _imports_random(tree) -> bool:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import) \
                    and any(a.name == "random" for a in node.names):
                return True
        return False
