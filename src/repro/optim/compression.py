"""Gradient compression for the cross-pod (DCN) axis.

int8 quantization with error feedback (EF-SGD style): the residual from each
round is carried in optimizer-side state and added back before the next
compression, so the bias vanishes over steps.

``compressed_pod_psum`` realizes the compressed all-reduce physically with
shard_map over the "pod" mesh axis: int8 payloads are all-gathered (4x fewer
DCN bytes than an f32 all-reduce ring) and summed locally in f32.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["ef_compress", "ef_decompress", "ef_round", "compressed_pod_psum",
           "shard_map_fn"]


def shard_map_fn():
    """Version-portable shard_map: ``jax.shard_map`` (new releases, kwarg
    ``check_vma``) or ``jax.experimental.shard_map`` (kwarg ``check_rep``).
    Returns a callable with the replication check disabled, or ``None`` when
    the installed jax has neither."""
    if hasattr(jax, "shard_map"):
        return functools.partial(jax.shard_map, check_vma=False)
    try:
        from jax.experimental.shard_map import shard_map
        return functools.partial(shard_map, check_rep=False)
    except ImportError:
        return None


def ef_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g -> (int8 payload, f32 scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_round(g: jax.Array, residual: jax.Array):
    """One error-feedback round. Returns (compressed-view grad, new residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = ef_compress(corrected)
    deq = ef_decompress(q, scale)
    return deq.astype(g.dtype), corrected - deq


def compressed_pod_psum(grads, mesh, *, axis: str = "pod"):
    """Physically-compressed gradient reduction over the pod axis.

    Inside shard_map each pod holds its local gradient shard; we int8-quantize,
    all_gather over ``axis`` (int8 on the wire), then dequantize and sum.
    """
    if axis not in mesh.axis_names:
        return grads

    def reduce_leaf(g):
        q, scale = ef_compress(g)
        qs = jax.lax.all_gather(q, axis)              # [n_pod, ...] int8
        ss = jax.lax.all_gather(scale, axis)          # [n_pod]
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
        return jnp.sum(deq, axis=0).astype(g.dtype)

    smap = shard_map_fn()
    if smap is None:
        raise NotImplementedError(
            "compressed_pod_psum needs shard_map (jax.shard_map or "
            "jax.experimental.shard_map); neither exists in this jax")
    specs = jax.tree.map(lambda _: P(), grads)
    fn = smap(lambda t: jax.tree.map(reduce_leaf, t),
              mesh=mesh, in_specs=(specs,), out_specs=specs)
    return fn(grads)
