from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_at_step, clip_by_global_norm
from repro.optim import compression
