"""Functional AdamW + LR schedules (cosine, WSD) + grad clipping.

No optax dependency — states are plain pytrees so the parallel layer can
attach ZeRO shardings to them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
           "lr_at_step"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # cosine | wsd | const
    wsd_decay_frac: float = 0.1     # MiniCPM: final decay phase fraction
    min_lr_frac: float = 0.1


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree.map(jnp.zeros_like, params),
                step=jnp.zeros((), jnp.int32))


def lr_at_step(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        base = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # warmup -> stable -> linear decay in the last wsd_decay_frac of steps
        decay_start = 1.0 - cfg.wsd_decay_frac
        frac = jnp.clip((t - decay_start) / cfg.wsd_decay_frac, 0.0, 1.0)
        base = 1.0 - (1.0 - cfg.min_lr_frac) * frac
    else:
        base = jnp.ones(())
    return cfg.lr * warm * base


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    factor = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * factor, grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at_step(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, mi, vi):
        mhat = mi / bc1
        vhat = vi / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, dict(m=m, v=v, step=step), dict(lr=lr, grad_norm=gnorm)
