"""Architecture registry: repro.configs.get_config('<arch-id>')."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "nemotron-4-15b": "nemotron_4_15b",
    "deepseek-7b": "deepseek_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "dbrx-132b": "dbrx_132b",
    "whisper-large-v3": "whisper_large_v3",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llama2-7b": "llama2_7b",
}

ARCHS = tuple(_MODULES)
ASSIGNED = tuple(a for a in ARCHS if a != "llama2-7b")


def get_config(arch: str) -> ModelConfig:
    import importlib
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG
