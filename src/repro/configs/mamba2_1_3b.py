"""Mamba2-1.3B [arXiv:2405.21060; unverified] — SSD, attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=50_280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    conv_width=4, scan_unit=("mamba",))
