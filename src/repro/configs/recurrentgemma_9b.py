"""RecurrentGemma-9B [arXiv:2402.19427; unverified] — RG-LRU + local attn 1:2.

38 layers = 12 x (rglru, rglru, attn_local) + (rglru, rglru) tail; MQA (kv=1),
sliding window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12_288, vocab=256_000,
    act="swiglu", window=2048,
    scan_unit=("rglru", "rglru", "attn_local"), scan_tail=("rglru", "rglru"))
