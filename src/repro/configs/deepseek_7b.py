"""DeepSeek-7B [arXiv:2401.02954; hf] — llama architecture."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=11_008, vocab=102_400,
    act="swiglu", scan_unit=("attn",))
