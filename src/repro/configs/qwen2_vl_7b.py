"""Qwen2-VL-7B [arXiv:2409.12191; hf] — M-RoPE backbone, vision stub."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18_944, vocab=152_064,
    act="swiglu", rope_kind="mrope", mrope_sections=(16, 24, 24),
    n_vision_tokens=64, scan_unit=("attn",),
    notes="vision frontend stubbed: input_specs() provides patch embeddings")
