"""DBRX-132B [hf:databricks/dbrx-base; unverified] — 16 experts, top-4."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=10_752, vocab=100_352,
    act="swiglu", n_experts=16, top_k=4, scan_unit=("attn_moe",))
