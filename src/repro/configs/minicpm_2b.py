"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like dense, WSD schedule."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="minicpm-2b", family="dense", n_layers=40, d_model=2304,
    n_heads=36, n_kv_heads=36, head_dim=64, d_ff=5760, vocab=122_753,
    act="swiglu", tie_embeddings=True, lr_schedule="wsd",
    scan_unit=("attn",),
    notes="WSD schedule wired via optim.schedule.wsd")
