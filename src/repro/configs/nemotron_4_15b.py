"""Nemotron-4-15B [arXiv:2402.16819; unverified] — GQA, squared-ReLU FFN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=24_576, vocab=256_000,
    act="sq_relu", scan_unit=("attn",))
