"""Model/shape configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: str = "swiglu"          # swiglu | sq_relu | gelu
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rope_kind: str = "default"   # default | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (recurrentgemma / RG-LRU) ---
    window: int = 0              # local-attention window (0 = global)
    scan_unit: Tuple[str, ...] = ("attn",)   # block types in one scan repeat
    scan_tail: Tuple[str, ...] = ()          # remainder layers (unscanned)
    # --- enc-dec (whisper) ---
    enc_layers: int = 0          # >0 => encoder-decoder
    frontend_stride: int = 4     # audio frames -> encoder positions (stub)
    # --- vlm stub ---
    n_vision_tokens: int = 0
    # --- numerics / schedule hints ---
    norm_eps: float = 1e-5
    lr_schedule: str = "cosine"  # cosine | wsd (minicpm)
    notes: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.scan_tail)
        assert body % len(self.scan_unit) == 0, (self.arch, body, self.scan_unit)
        return body // len(self.scan_unit)

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.act == "swiglu":
            per_mlp = 3 * d * self.d_ff
        else:
            per_mlp = 2 * d * self.d_ff
        if self.n_experts:
            per_mlp = per_mlp * self.n_experts + d * self.n_experts
        per_ssm = 0
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per_ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            per_attn, per_mlp = 0, 0
        layers = self.n_layers * (per_attn + per_mlp + per_ssm)
        if self.family == "hybrid":
            # RG-LRU blocks replace attention in 2/3 of layers; roughly same size
            pass
        if self.enc_layers:
            layers = (self.enc_layers + self.n_layers) * (per_attn * 1.5 + per_mlp)
        return int(emb + layers)

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (6*N_active*D flops convention)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        per_mlp_total = (3 if self.act == "swiglu" else 2) * d * self.d_ff
        dense_like = self.param_count() \
            - self.n_layers * per_mlp_total * self.n_experts \
            + self.n_layers * per_mlp_total * self.top_k
        return int(dense_like)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    unit = cfg.scan_unit
    tail = cfg.scan_tail
    n_layers = len(unit) + len(tail) if (len(unit) + len(tail)) > 1 else 2
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_headdim=16 if cfg.ssm_headdim else 0,
        ssm_chunk=8,
        window=min(cfg.window, 8),
        enc_layers=min(cfg.enc_layers, 2),
        n_vision_tokens=min(cfg.n_vision_tokens, 4),
        mrope_sections=(4, 2, 2),
    )
