"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; hf] — qk-norm, GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144, vocab=151_936,
    act="swiglu", qk_norm=True, scan_unit=("attn",))
