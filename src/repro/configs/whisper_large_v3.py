"""Whisper-large-v3 [arXiv:2212.04356; unverified] — enc-dec, conv stub.

"32L" counts encoder depth; the decoder mirrors it (as in the real model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, head_dim=64, d_ff=5120, vocab=51_866,
    act="gelu", rope_kind="none", enc_layers=32, frontend_stride=4,
    scan_unit=("attn",),
    notes="conv frontend stubbed: input_specs() provides frame embeddings")
