"""Llama-2-7B — the paper's own evaluation model (Table 1/2/4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama2-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=11_008, vocab=32_000,
    act="swiglu", scan_unit=("attn",))
