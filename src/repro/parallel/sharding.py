"""Param / batch / cache -> PartitionSpec rules.

Tensor parallelism over the "model" axis (Megatron column->row pairs), data
parallelism over ("pod", "data"). Dims are sharded only when divisible by the
axis size — GSPMD padding is avoided on purpose so shard shapes stay exact.
Scanned parameter stacks have a leading repeat dim which is never sharded.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.packing import per_word, unit_codes
from repro.core.quantized import (QUANTIZABLE, TP_ROW, _PAYLOAD_KEYS,
                                  _meta_key)

__all__ = ["param_specs", "batch_specs", "cache_specs_tree", "ShardingRules",
           "named", "zero_shard_specs", "dp_axes", "dp_size", "logits_spec",
           "payload_word_unit"]

# logical (unstacked) rank per trailing param name
_COL_PARALLEL = {"wq", "wk", "wv", "w1", "w3", "wx", "wg", "wr", "wi",
                 "in_proj", "router"}       # [D, F] -> shard F
_ROW_PARALLEL = {"wo", "w2", "out_proj"}    # [F, D] -> shard F (contracting)
_REPLICATED_1D = {"ln", "final_ln", "enc_ln", "dec_ln", "q_norm", "k_norm",
                  "out_norm", "conv_bias", "a_log", "d_skip", "dt_bias",
                  "lam", "scale", "mu", "bits", "g"}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    # math.prod, not jnp.prod: this is a host-side integer used while
    # *building* specs — allocating a device array here would round-trip
    # through the backend on every spec build.
    return math.prod(mesh.shape[a] for a in dp_axes(mesh))


def _tp(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _leaf_spec(name: str, shape: Tuple[int, ...], tp: int) -> P:
    nd = len(shape)
    if name in _REPLICATED_1D:
        return P(*([None] * nd))
    if name == "embed":                      # [V, D]
        if _div(shape[1], tp):
            return P(None, "model")
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "head":                       # [D, V]
        if _div(shape[1], tp):
            return P(None, "model")
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "packed":                     # [lead..., K, n_words] uint32 codes
        lead = [None] * (nd - 2)
        out = "model" if _div(shape[-1], tp) else None
        return P(*(lead + [None, out]))
    if name == "conv":                       # [W, C] depthwise
        lead = nd - 2
        spec = ("model",) if _div(shape[-1], tp) else (None,)
        return P(*([None] * (lead + 1) + list(spec)))
    if name in _COL_PARALLEL or name in _ROW_PARALLEL:
        lead = nd - 2
        if nd >= 3 and name != "conv":
            # stacked: [R, ...] or MoE experts [E, D, F]
            # MoE expert dim is dim -3 when logical rank 3 (we mark via size)
            pass
        d_in, d_out = shape[-2], shape[-1]
        if name in _COL_PARALLEL:
            spec = (None, "model") if _div(d_out, tp) else \
                (("model", None) if _div(d_in, tp) else (None, None))
        else:
            spec = ("model", None) if _div(d_in, tp) else (None, None)
        lead_spec = [None] * (nd - 2)
        # MoE experts: prefer expert-parallel over feature TP
        return P(*(lead_spec + list(spec)))
    # default: replicate
    return P(*([None] * nd))


def payload_word_unit(bits: int, d: int) -> int:
    """``core.packing.unit_codes`` expressed in packed uint32 words — the
    granularity shard boundaries of ``packed``'s last dim must respect."""
    return unit_codes(bits, d) // per_word(bits)


def _payload_leaf_spec(wname: str, lname: str, shape: Tuple[int, ...],
                       tp: int, meta) -> P:
    """QuantTensor payload leaves ({packed, g, mu, scale} under a quantizable
    weight name).

    Column-parallel weights shard ``packed`` along n_words in word-unit-
    aligned chunks (G / mu / scale are per-K-group side info shared by every
    N column — replicated).  Row-parallel weights shard K: ``packed`` along
    its K dim in whole code groups, and g / mu / scale along their group dim
    together with it, so each device holds exactly the side info its K-shard
    decodes with.  Anything indivisible stays replicated (no GSPMD padding).
    """
    nd = len(shape)
    parts = [None] * nd
    if wname in TP_ROW:
        if meta is None or meta.n_groups % tp:
            return P(*parts)                 # keep all four leaves consistent
        if lname == "packed":                # [lead..., K, n_words]
            parts[-2] = "model"
        elif lname == "g":                   # [lead..., n_groups, d, d]
            parts[-3] = "model"
        else:                                # mu / scale [lead..., n_groups]
            parts[-1] = "model"
        return P(*parts)
    # column-parallel
    if lname == "packed":
        if meta is not None:
            # aligned shards, no pad codes — the same condition as
            # kernels.ops.tp_shardable, via the shared unit_codes helper
            ok = meta.n % (tp * unit_codes(meta.bits, meta.d)) == 0
        else:
            ok = _div(shape[-1], tp)         # legacy: plain word divisibility
        if ok:
            parts[-1] = "model"
    return P(*parts)


def _moe_leaf_spec(name: str, shape: Tuple[int, ...], tp: int,
                   expert_parallel: bool) -> Optional[P]:
    """MoE weights [R, E, D, F]: shard the expert dim when divisible."""
    if name in ("w1", "w2", "w3") and len(shape) >= 3:
        e = shape[-3]
        if expert_parallel and _div(e, tp):
            lead = [None] * (len(shape) - 3)
            return P(*(lead + ["model", None, None]))
    return None


def _moe_payload_spec(lname: str, shape: Tuple[int, ...], tp: int,
                      expert_parallel: bool) -> Optional[P]:
    """Quantized MoE payload leaves: shard the expert dim (mirrors the dense
    expert-parallel rule; all four leaves shard the same dim so one expert's
    payload stays co-located)."""
    nd = len(shape)
    edim = {"packed": nd - 3, "g": nd - 4, "mu": nd - 2, "scale": nd - 2}[lname]
    if expert_parallel and edim >= 0 and _div(shape[edim], tp):
        parts = [None] * nd
        parts[edim] = "model"
        return P(*parts)
    return None


def param_specs(params, mesh: Mesh, *, expert_parallel: bool = True,
                moe_paths: bool = True, qmeta=None):
    """PartitionSpec pytree matching ``params``.

    ``qmeta`` (the ``meta_by_key`` dict from ``core.quantized``) enables the
    QuantTensor-aware payload rules: column-parallel packed codes shard along
    n_words in word-unit-aligned chunks, row-parallel payloads shard K /
    their group dim — matching the shard_map execution path in
    ``kernels.ops``.  Without it, payload leaves fall back to storage-level
    word sharding with replicated side info."""
    tp = _tp(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        in_moe = "moe" in names
        wname = names[-2] if len(names) >= 2 else ""
        if name in _PAYLOAD_KEYS and wname in QUANTIZABLE:
            if in_moe and moe_paths:
                s = _moe_payload_spec(name, leaf.shape, tp, expert_parallel)
                if s is not None:
                    return s
            meta = qmeta.get(_meta_key(tuple(names[:-1]))) if qmeta else None
            return _payload_leaf_spec(wname, name, leaf.shape, tp, meta)
        if in_moe and moe_paths:
            s = _moe_leaf_spec(name, leaf.shape, tp, expert_parallel)
            if s is not None:
                return s
        return _leaf_spec(name, leaf.shape, tp)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def zero_shard_specs(specs, params, mesh: Mesh, axis: str = "data"):
    """ZeRO-1: additionally shard optimizer moments over the data axis on the
    first unsharded divisible dim."""
    n = mesh.shape[axis]

    def add(spec, leaf):
        parts = list(spec)
        parts += [None] * (leaf.ndim - len(parts))
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % n == 0 and dim >= n:
                parts[i] = axis
                return P(*parts)
        return spec

    return jax.tree.map(add, specs, params)


def batch_specs(batch, mesh: Mesh):
    """Shard the batch dim over (pod, data) when divisible; else replicate."""
    axes = dp_axes(mesh)
    n = dp_size(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        bdim = 1 if name == "pos3" else 0
        parts = [None] * leaf.ndim
        if leaf.shape[bdim] % n == 0:
            parts[bdim] = axes if len(axes) > 1 else axes[0]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


# Paged-pool leaves (kernels.kv_cache.pool_init): kp/vp [R?, num_blocks,
# block_size, KV, hd], ksc/vsc [R?, num_blocks, block_size, KV].  These are
# NOT dense [B, S, ...] layouts: the pool dims (num_blocks, block_size) index
# physical blocks shared by every slot, so sharding either one over the data
# axes would scatter one slot's history across data replicas.  paged_glvq
# codebook leaves (kg/kgi/vg/vgi [R?, KV, d, d], kmu/vmu [R?, KV]) shard the
# same KV-head dim and replicate over data like the pools they decode.
_PAGED_POOLS = {"kp": -2, "vp": -2, "ksc": -1, "vsc": -1,   # name -> KV dim
                "kg": -3, "kgi": -3, "vg": -3, "vgi": -3,
                "kmu": -1, "vmu": -1}


def cache_specs_tree(cache, mesh: Mesh, cfg=None):
    """KV caches: batch over (pod,data); heads/channels over model if divisible.

    Paged pools replicate over the data axes (the block pool is shared by all
    slots) and shard only the KV-head dim over model when divisible; the block
    table is fully replicated — its host-side ``SlotPages`` mirror is
    unsharded, and a data-sharded device copy would desynchronize from it."""
    axes = dp_axes(mesh)
    n = dp_size(mesh)
    tp = _tp(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        shape = leaf.shape
        parts = [None] * leaf.ndim
        if name in _PAGED_POOLS:
            kv = _PAGED_POOLS[name]
            if shape[kv] % tp == 0:
                parts[kv] = "model"
            return P(*parts)
        if name in ("table", "lt"):          # int32 [slots, blocks_per_slot]
            # "table" mirrors the host-side SlotPages allocator; "lt" is a
            # local layer's baked-in ring ownership — both stay replicated
            return P(*parts)
        # layouts: k/v [R?, B, S, KV, hd]; state [R?, B, H, P, N] | [R?, B, R];
        # conv [R?, B, W, C]; whisper self_k [L, B, S, KV, hd]
        bdim = 1 if leaf.ndim >= 3 else 0
        if shape[bdim] % n == 0:
            parts[bdim] = axes if len(axes) > 1 else axes[0]
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            if shape[-2] % tp == 0:
                parts[-2] = "model"
            elif shape[-3] % tp == 0:
                # GQA kv-heads < TP: shard the SEQUENCE dim instead, so
                # decode attention becomes flash-decoding-style sequence
                # parallelism (GSPMD reduces the softmax stats, ~KB-scale
                # collectives) rather than all-gathering the whole cache.
                parts[-3] = "model"
        elif name == "state" and leaf.ndim >= 4:
            if shape[2] % tp == 0:
                parts[2] = "model"
        elif name in ("state", "conv"):
            if shape[-1] % tp == 0:
                parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def paged_attn_specs(pools, *, chunked: bool = False):
    """shard_map specs for the fused paged-attention call
    (``kernels.attention.paged_attention``).

    Heads shard over "model": q [B, T, H, hd] and the pools' KV-head dim
    (kp/vp [nb, bs, KV, hd], ksc/vsc [nb, bs, KV]) split, the block table /
    positions / lens replicate (matching ``cache_specs_tree``), and the
    in-flight chunk keys [B, T, KV, hd] split with the pools.  Each shard
    owns whole (kv-head, query-group) pairs, so no collective is needed;
    the [B, T, H*hd] output concatenates head shards along its flattened
    last dim.  Returns (in_specs, out_spec) matching the positional args
    (q, pools, table, pos, lens[, k_chunk, v_chunk]).

    Specs are keyed by leaf NAME, not ndim: paged_glvq codebook leaves
    (kg/vg [KV, d, d], kmu/vmu [KV]) lead with the KV-head dim, unlike the
    block pools."""
    head4 = P(None, None, "model", None)
    by_name = {"kp": head4, "vp": head4,
               "ksc": P(None, None, "model"), "vsc": P(None, None, "model"),
               "kg": P("model", None, None), "vg": P("model", None, None),
               "kmu": P("model"), "vmu": P("model")}
    pool_specs = {n: by_name[n] for n in pools}
    in_specs = (head4, pool_specs, P(None, None), P(None), P(None))
    if chunked:
        in_specs = in_specs + (head4, head4)
    return in_specs, P(None, None, "model")


def logits_spec(vocab: int, mesh: Mesh, batch: int):
    axes = dp_axes(mesh)
    n = dp_size(mesh)
    b = (axes if len(axes) > 1 else axes[0]) if batch % n == 0 else None
    v = "model" if vocab % _tp(mesh) == 0 else None
    return P(b, v)


def named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
