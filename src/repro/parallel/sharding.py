"""Param / batch / cache -> PartitionSpec rules.

Tensor parallelism over the "model" axis (Megatron column->row pairs), data
parallelism over ("pod", "data"). Dims are sharded only when divisible by the
axis size — GSPMD padding is avoided on purpose so shard shapes stay exact.
Scanned parameter stacks have a leading repeat dim which is never sharded.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs_tree", "ShardingRules",
           "named", "zero_shard_specs", "dp_axes", "dp_size", "logits_spec"]

# logical (unstacked) rank per trailing param name
_COL_PARALLEL = {"wq", "wk", "wv", "w1", "w3", "wx", "wg", "wr", "wi",
                 "in_proj", "router"}       # [D, F] -> shard F
_ROW_PARALLEL = {"wo", "w2", "out_proj"}    # [F, D] -> shard F (contracting)
_REPLICATED_1D = {"ln", "final_ln", "enc_ln", "dec_ln", "q_norm", "k_norm",
                  "out_norm", "conv_bias", "a_log", "d_skip", "dt_bias",
                  "lam", "scale", "mu", "bits", "g"}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(jnp.prod(jnp.asarray([mesh.shape[a] for a in dp_axes(mesh)])))


def _tp(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _leaf_spec(name: str, shape: Tuple[int, ...], tp: int) -> P:
    nd = len(shape)
    if name in _REPLICATED_1D:
        return P(*([None] * nd))
    if name == "embed":                      # [V, D]
        if _div(shape[1], tp):
            return P(None, "model")
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "head":                       # [D, V]
        if _div(shape[1], tp):
            return P(None, "model")
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "packed":                     # [lead..., K, n_words] uint32 codes
        lead = [None] * (nd - 2)
        out = "model" if _div(shape[-1], tp) else None
        return P(*(lead + [None, out]))
    if name == "conv":                       # [W, C] depthwise
        lead = nd - 2
        spec = ("model",) if _div(shape[-1], tp) else (None,)
        return P(*([None] * (lead + 1) + list(spec)))
    if name in _COL_PARALLEL or name in _ROW_PARALLEL:
        lead = nd - 2
        if nd >= 3 and name != "conv":
            # stacked: [R, ...] or MoE experts [E, D, F]
            # MoE expert dim is dim -3 when logical rank 3 (we mark via size)
            pass
        d_in, d_out = shape[-2], shape[-1]
        if name in _COL_PARALLEL:
            spec = (None, "model") if _div(d_out, tp) else \
                (("model", None) if _div(d_in, tp) else (None, None))
        else:
            spec = ("model", None) if _div(d_in, tp) else (None, None)
        lead_spec = [None] * (nd - 2)
        # MoE experts: prefer expert-parallel over feature TP
        return P(*(lead_spec + list(spec)))
    # default: replicate
    return P(*([None] * nd))


def _moe_leaf_spec(name: str, shape: Tuple[int, ...], tp: int,
                   expert_parallel: bool) -> Optional[P]:
    """MoE weights [R, E, D, F]: shard the expert dim when divisible."""
    if name in ("w1", "w2", "w3") and len(shape) >= 3:
        e = shape[-3]
        if expert_parallel and _div(e, tp):
            lead = [None] * (len(shape) - 3)
            return P(*(lead + ["model", None, None]))
    return None


def param_specs(params, mesh: Mesh, *, expert_parallel: bool = True,
                moe_paths: bool = True):
    """PartitionSpec pytree matching ``params``."""
    tp = _tp(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        in_moe = "moe" in names
        if in_moe and moe_paths:
            s = _moe_leaf_spec(name, leaf.shape, tp, expert_parallel)
            if s is not None:
                return s
        return _leaf_spec(name, leaf.shape, tp)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def zero_shard_specs(specs, params, mesh: Mesh, axis: str = "data"):
    """ZeRO-1: additionally shard optimizer moments over the data axis on the
    first unsharded divisible dim."""
    n = mesh.shape[axis]

    def add(spec, leaf):
        parts = list(spec)
        parts += [None] * (leaf.ndim - len(parts))
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % n == 0 and dim >= n:
                parts[i] = axis
                return P(*parts)
        return spec

    return jax.tree.map(add, specs, params)


def batch_specs(batch, mesh: Mesh):
    """Shard the batch dim over (pod, data) when divisible; else replicate."""
    axes = dp_axes(mesh)
    n = dp_size(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        bdim = 1 if name == "pos3" else 0
        parts = [None] * leaf.ndim
        if leaf.shape[bdim] % n == 0:
            parts[bdim] = axes if len(axes) > 1 else axes[0]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_specs_tree(cache, mesh: Mesh, cfg=None):
    """KV caches: batch over (pod,data); heads/channels over model if divisible."""
    axes = dp_axes(mesh)
    n = dp_size(mesh)
    tp = _tp(mesh)

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        shape = leaf.shape
        parts = [None] * leaf.ndim
        # layouts: k/v [R?, B, S, KV, hd]; state [R?, B, H, P, N] | [R?, B, R];
        # conv [R?, B, W, C]; whisper self_k [L, B, S, KV, hd]
        bdim = 1 if leaf.ndim >= 3 else 0
        if shape[bdim] % n == 0:
            parts[bdim] = axes if len(axes) > 1 else axes[0]
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            if shape[-2] % tp == 0:
                parts[-2] = "model"
            elif shape[-3] % tp == 0:
                # GQA kv-heads < TP: shard the SEQUENCE dim instead, so
                # decode attention becomes flash-decoding-style sequence
                # parallelism (GSPMD reduces the softmax stats, ~KB-scale
                # collectives) rather than all-gathering the whole cache.
                parts[-3] = "model"
        elif name == "state" and leaf.ndim >= 4:
            if shape[2] % tp == 0:
                parts[2] = "model"
        elif name in ("state", "conv"):
            if shape[-1] % tp == 0:
                parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def logits_spec(vocab: int, mesh: Mesh, batch: int):
    axes = dp_axes(mesh)
    n = dp_size(mesh)
    b = (axes if len(axes) > 1 else axes[0]) if batch % n == 0 else None
    v = "model" if vocab % _tp(mesh) == 0 else None
    return P(b, v)


def named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
