"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, S_audio, D]. The encoder is non-causal
self-attention; the decoder interleaves causal self-attention, cross-attention
to the encoder output, and a GELU MLP. Sinusoidal positions on both sides
(we use RMSNorm rather than LayerNorm-with-bias throughout the repo; noted in
DESIGN.md as an intentional uniformity deviation).

Quantized execution: like ``models.lm``, ``forward`` / ``decode_step`` accept
``qmeta`` + ``backend`` and wrap packed payloads into QuantTensor nodes, so
encoder, decoder self/cross-attention and MLP matmuls all dispatch through
the engine (the output head stays dense).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qtensor
from repro.core.qtensor import QuantTensor
from repro.models import layers
from repro.models.layers import linear, rms_norm

Params = Dict[str, Any]


def sinusoid(s: int, d: int, dtype):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    out = jnp.zeros((s, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return out.astype(dtype)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return dict(attn=layers.attn_init(k1, cfg), mlp=layers.mlp_init(k2, cfg))


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(attn=layers.attn_init(k1, cfg),
                xattn=layers.attn_init(k2, cfg),
                mlp=layers.mlp_init(k3, cfg))


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return dict(
        enc_blocks=jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        dec_blocks=jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        embed=jax.random.normal(ks[2], (cfg.vocab, cfg.d_model), jnp.float32)
        * cfg.d_model ** -0.5,
        head=layers.dense_init(ks[3], cfg.d_model, cfg.vocab),
        enc_ln=jnp.ones((cfg.d_model,), jnp.float32),
        dec_ln=jnp.ones((cfg.d_model,), jnp.float32),
    )


def encode(params: Params, frames, cfg: ModelConfig, *, remat: bool = False,
           unroll: int = 1):
    """frames [B, S_a, D] (precomputed frontend embeddings) -> [B, S_a, D]."""
    x = frames + sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def block(x, p):
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        x = x + layers.attention(p["attn"], h, cfg, pos, causal=False)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg)

    fn = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda x, p: (fn(x, p), None), x, params["enc_blocks"],
                        unroll=unroll)
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def decode_train(params: Params, tokens, enc_out, cfg: ModelConfig,
                 *, remat: bool = False, unroll: int = 1):
    dtype = enc_out.dtype
    x = params["embed"].astype(dtype)[tokens]
    x = x + sinusoid(x.shape[1], cfg.d_model, dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def block(x, p):
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        x = x + layers.attention(p["attn"], h, cfg, pos, causal=True)
        h = rms_norm(x, p["xattn"]["ln"], cfg.norm_eps)
        x = x + layers.attention(p["xattn"], h, cfg, pos, causal=False,
                                 cross_kv=enc_out)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg)

    fn = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda x, p: (fn(x, p), None), x, params["dec_blocks"],
                        unroll=unroll)
    x = rms_norm(x, params["dec_ln"], cfg.norm_eps)
    return (x @ params["head"].astype(dtype)).astype(jnp.float32)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, dtype=jnp.bfloat16, remat: bool = False, unroll: int = 1,
            qmeta=None, backend=None, mesh=None):
    if qmeta:
        params = qtensor.wrap_tree(params, qmeta, backend=backend, mesh=mesh)
    enc_out = encode(params, batch["frames"].astype(dtype), cfg, remat=remat,
                     unroll=unroll)
    return decode_train(params, batch["tokens"], enc_out, cfg, remat=remat,
                        unroll=unroll)


def loss_fn(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            remat: bool = True, unroll: int = 1, qmeta=None, backend=None):
    logits = forward(params, batch, cfg, dtype=dtype, remat=remat,
                     unroll=unroll, qmeta=qmeta, backend=backend)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_init(cfg: ModelConfig, batch: int, s_dec: int, s_enc: int, dtype):
    """Self-attn KV cache per decoder layer + precomputed cross K/V."""
    l = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.hd
    return dict(
        self_k=jnp.zeros((l, batch, s_dec, kv, hd), dtype),
        self_v=jnp.zeros((l, batch, s_dec, kv, hd), dtype),
        cross_k=jnp.zeros((l, batch, s_enc, kv, hd), dtype),
        cross_v=jnp.zeros((l, batch, s_enc, kv, hd), dtype),
    )


def prefill_cross(params: Params, enc_out, cfg: ModelConfig, s_dec: int,
                  *, qmeta=None, backend=None, mesh=None):
    """Run the encoder-side of serving: precompute per-layer cross K/V."""
    if qmeta:
        params = qtensor.wrap_tree(params, qmeta, backend=backend, mesh=mesh)
    b, se = enc_out.shape[:2]
    dtype = enc_out.dtype

    def proj(w):
        # w is the stacked [L, D, KV*hd] cross projection; QuantTensor's
        # stacked matmul broadcasts a 2-D activation against every layer
        # slice (flatten [B, Se, D] -> [B*Se, D]: the engine's broadcast
        # path only handles matrix activations).
        if isinstance(w, QuantTensor):
            y = w.matmul(enc_out.reshape(b * se, -1), out_dtype=dtype,
                         zipped=False)
        else:
            y = jnp.einsum("bsd,ldn->lbsn", enc_out, w.astype(dtype))
        return y.reshape(-1, b, se, cfg.n_kv_heads, cfg.hd)

    ck = proj(params["dec_blocks"]["xattn"]["wk"])
    cv = proj(params["dec_blocks"]["xattn"]["wv"])
    cache = cache_init(cfg, b, s_dec, se, dtype)
    return dict(cache, cross_k=ck, cross_v=cv)


def decode_step(params: Params, cache, token, pos, cfg: ModelConfig,
                *, dtype=jnp.bfloat16, unroll: int = 1, qmeta=None,
                backend=None, mesh=None):
    """One decoder token against cached self-KV + cross-KV."""
    if qmeta:
        params = qtensor.wrap_tree(params, qmeta, backend=backend, mesh=mesh)
    b = token.shape[0]
    x = params["embed"].astype(dtype)[token][:, None, :]
    s_dec = cache["self_k"].shape[2]
    pe = sinusoid(s_dec, cfg.d_model, dtype)[pos]
    x = x + (pe[:, None, :] if pos.ndim else pe[None, None, :])
    n_rep = cfg.n_heads // cfg.n_kv_heads

    def body(x, inp):
        p, sk, sv, ck, cv = inp
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        out, new_c = layers.attention_decode(p["attn"], h, cfg,
                                             dict(k=sk, v=sv), pos)
        x = x + out
        # cross attention against precomputed enc K/V
        h = rms_norm(x, p["xattn"]["ln"], cfg.norm_eps)
        q = linear(h, p["xattn"]["wq"], dtype).reshape(
            b, 1, cfg.n_kv_heads, n_rep, cfg.hd)
        scores = jnp.einsum("bsgrd,btgd->bgrst", q, ck).astype(jnp.float32)
        probs = jax.nn.softmax(scores * cfg.hd ** -0.5, -1).astype(dtype)
        out = jnp.einsum("bgrst,btgd->bsgrd", probs, cv).reshape(b, 1, -1)
        x = x + linear(out, p["xattn"]["wo"], dtype)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        x = x + layers.mlp(p["mlp"], h, cfg)
        return x, (new_c["k"], new_c["v"])

    xs = (params["dec_blocks"], cache["self_k"], cache["self_v"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv) = jax.lax.scan(body, x, xs, unroll=unroll)
    x = rms_norm(x, params["dec_ln"], cfg.norm_eps)
    logits = (x[:, 0] @ params["head"].astype(dtype)).astype(jnp.float32)
    new_cache = dict(cache, self_k=nk, self_v=nv)
    return logits, new_cache
