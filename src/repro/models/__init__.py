"""Model zoo: unified LM (dense/moe/vlm/hybrid/ssm) + whisper enc-dec."""
from repro.models import registry
