"""Mamba-2 (SSD, state-space duality) block — chunked training scan + O(1) decode.

Implements the minimal SSD algorithm (Dao & Gu 2024): intra-chunk quadratic
term + inter-chunk state recurrence, with ngroups=1 (B/C shared across heads),
causal conv1d frontend and gated RMSNorm output.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, linear, rms_norm

Params = Dict[str, Any]


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_headdim
    return d_in, n_heads, cfg.ssm_state


def mamba_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, nh, ns = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * ns
    return dict(
        ln=jnp.ones((d,), jnp.float32),
        # in_proj -> [z (gate), x, B, C, dt]
        in_proj=dense_init(ks[0], d, 2 * d_in + 2 * ns + nh),
        conv=jax.random.normal(ks[1], (cfg.conv_width, conv_ch), jnp.float32) * 0.1,
        conv_bias=jnp.zeros((conv_ch,), jnp.float32),
        a_log=jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        d_skip=jnp.ones((nh,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        out_norm=jnp.ones((d_in,), jnp.float32),
        out_proj=dense_init(ks[3], d_in, d),
    )


def _segsum(a):
    """a [..., T] -> [..., T, T]: sum_{k=j+1..i} a_k for j <= i else -inf."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x, dt, a, b, c, chunk: int):
    """Chunked SSD. x [B,L,H,P], dt [B,L,H], a [H] (negative), b/c [B,L,N].

    Returns y [B,L,H,P] (no skip/gate). L must be a multiple of ``chunk``.
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    nc = l // chunk
    da = dt * a[None, None, :]                                # [B,L,H]
    xd = x * dt[..., None]
    # chunk
    xc = xd.reshape(bsz, nc, chunk, h, p)
    dac = da.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # [B,H,C,Q]
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)
    a_cum = jnp.cumsum(dac, axis=-1)                           # [B,H,C,Q]

    # 1) intra-chunk (quadratic) term
    lmat = jnp.exp(_segsum(dac))                               # [B,H,C,Q,Q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, lmat, xc)

    # 2) per-chunk right states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # [B,H,C,Q]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                      # [B,H,C]

    def step(carry, inp):
        st, dec = inp                                          # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit PREVIOUS

    init = jnp.zeros((bsz, h, p, n), x.dtype)
    _, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [B,C,H,P,N]

    # 4) chunk-input contribution
    state_decay_out = jnp.exp(a_cum)                           # [B,H,C,Q]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay_out)
    return (y_diag + y_off).reshape(bsz, l, h, p)


def _conv1d(u, w, bias):
    """Causal depthwise conv. u [B,L,C], w [W,C]."""
    width = w.shape[0]
    up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + bias[None, None, :]


def mamba_forward(p, x, cfg: ModelConfig):
    """Training/prefill forward. x [B,L,D] -> [B,L,D]."""
    bsz, l, d = x.shape
    d_in, nh, ns = ssm_dims(cfg)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = linear(h, p["in_proj"], x.dtype)
    z, xs, b, c, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ns, 2 * d_in + 2 * ns], axis=-1)
    xbc = _conv1d(jnp.concatenate([xs, b, c], axis=-1),
                  p["conv"].astype(x.dtype), p["conv_bias"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [d_in, d_in + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(bsz, l, nh, cfg.ssm_headdim)
    pad = -l % cfg.ssm_chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan(xh.astype(jnp.float32), dt, a,
                 b.astype(jnp.float32), c.astype(jnp.float32), cfg.ssm_chunk)
    y = y[:, :l].astype(x.dtype)
    y = y + xh[:, :l] * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, l, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return linear(y, p["out_proj"], x.dtype)


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype):
    d_in, nh, ns = ssm_dims(cfg)
    conv_ch = d_in + 2 * ns
    return dict(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        state=jnp.zeros((batch, nh, cfg.ssm_headdim, ns), jnp.float32),
    )


def _chunk_conv(timeline, w, bias, t: int):
    """Causal depthwise conv over a [B, W-1+T, C] timeline (conv cache ++
    slab): token tau's window is timeline[tau : tau+W]."""
    width = w.shape[0]
    out = sum(timeline[:, i:i + t] * w[i][None, None, :] for i in range(width))
    return out + bias[None, None, :]


def advance_conv_cache(timeline, lens, width: int):
    """New conv cache = last (width-1) VALID timeline entries per slot.

    timeline [B, width-1+T, C] is (old cache ++ slab inputs); a slot that
    consumed ``lens[b]`` tokens advances its window to timeline rows
    [lens[b], lens[b]+width-1) — slots with lens=0 keep their cache."""
    idx = lens[:, None] + jnp.arange(width - 1)[None]          # [B, W-1]
    return jnp.take_along_axis(timeline, idx[..., None], axis=1)


def mamba_chunk(p, x, cfg: ModelConfig, cache, valid):
    """Chunked serving step: projections run once over the whole [B, T] slab
    (matmuls at M = B*T — where the fused GLVQ kernels pay off) and only the
    elementwise state recurrence scans over T.  valid [B, T] masks pad slab
    positions: their conv inputs and state contributions are skipped, so the
    result matches token-by-token decode exactly.  T=1 is plain decode."""
    bsz, t, _ = x.shape
    d_in, nh, ns = ssm_dims(cfg)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = linear(h, p["in_proj"], x.dtype)                  # [B, T, ...]
    z, xs, b, c, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ns, 2 * d_in + 2 * ns], axis=-1)
    xbc_new = jnp.concatenate([xs, b, c], axis=-1)             # [B, T, C]
    timeline = jnp.concatenate([cache["conv"], xbc_new], axis=1)
    w = p["conv"].astype(x.dtype)
    xbc = _chunk_conv(timeline, w, p["conv_bias"].astype(x.dtype), t)
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [d_in, d_in + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, None])                           # [B, T, H]
    xh = xs.reshape(bsz, t, nh, cfg.ssm_headdim).astype(jnp.float32)
    inc = (dt[..., None] * xh)[..., None] \
        * b[:, :, None, None, :].astype(jnp.float32)           # [B,T,H,P,N]
    da = jnp.where(valid[..., None], da, 1.0)                  # pad: a=1, b=0
    inc = jnp.where(valid[..., None, None, None], inc, 0.0)

    def step(state, inp):
        da_t, inc_t, c_t = inp
        state = state * da_t[..., None, None] + inc_t
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    state, ys = jax.lax.scan(
        step, cache["state"],
        (da.transpose(1, 0, 2), inc.transpose(1, 0, 2, 3, 4),
         c.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3)                               # [B, T, H, P]
    y = y.astype(x.dtype) + xh.astype(x.dtype) \
        * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, t, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    lens = jnp.sum(valid.astype(jnp.int32), axis=1)
    new_cache = dict(conv=advance_conv_cache(timeline, lens, cfg.conv_width),
                     state=state)
    return linear(y, p["out_proj"], x.dtype), new_cache


def mamba_decode(p, x, cfg: ModelConfig, cache):
    """One-token decode — the T=1 specialization of ``mamba_chunk``:
    O(1) in context length. x [B,1,D]."""
    return mamba_chunk(p, x, cfg, cache,
                       jnp.ones((x.shape[0], 1), jnp.bool_))
