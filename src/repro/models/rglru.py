"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: u -> (gate branch: GeLU(W_g u)) * (recurrent branch: RG-LRU(conv1d(W_x u)))
       -> W_o.
RG-LRU:  r_t = sigmoid(W_r v_t); i_t = sigmoid(W_i v_t)
         log a_t = -c * softplus(Lambda) * r_t        (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * v_t)
Training uses an associative scan over time; decode is the one-step update.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, linear, rms_norm

Params = Dict[str, Any]
_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    r = d  # lru width == d_model (RecurrentGemma-9B)
    ks = jax.random.split(key, 6)
    return dict(
        ln=jnp.ones((d,), jnp.float32),
        wx=dense_init(ks[0], d, r),
        wg=dense_init(ks[1], d, r),
        conv=jax.random.normal(ks[2], (cfg.conv_width, r), jnp.float32) * 0.1,
        conv_bias=jnp.zeros((r,), jnp.float32),
        wr=dense_init(ks[3], r, r),
        wi=dense_init(ks[4], r, r),
        lam=jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, r).astype(jnp.float32) * _C) / _C + 1e-8),
        wo=dense_init(ks[5], r, d),
    )


def _conv1d(u, w, bias):
    width = w.shape[0]
    up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + bias[None, None, :]


def _gates(p, v):
    r = jax.nn.sigmoid(linear(v, p["wr"]).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(v, p["wi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * v.astype(jnp.float32)
    return a, b


def rglru_forward(p, x, cfg: ModelConfig):
    """x [B,L,D] -> [B,L,D] via associative scan (parallel over time)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(linear(h, p["wg"], x.dtype))
    v = _conv1d(linear(h, p["wx"], x.dtype),
                p["conv"].astype(x.dtype), p["conv_bias"].astype(x.dtype))
    a, b = _gates(p, v)                                   # [B,L,R] f32

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = linear(hseq.astype(x.dtype) * gate, p["wo"], x.dtype)
    return y


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype):
    r = cfg.d_model
    return dict(
        conv=jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
        state=jnp.zeros((batch, r), jnp.float32),
    )


def rglru_chunk(p, x, cfg: ModelConfig, cache, valid):
    """Chunked serving step: all four projections (wg/wx/wr/wi) run once
    over the whole [B, T] slab; only the elementwise h_t = a_t h_{t-1} + b_t
    recurrence scans over T, in the same sequential order as one-step decode
    (bit-parity with the token-by-token oracle — an associative scan would
    re-associate the f32 products).  valid [B, T] masks pad positions: their
    conv inputs and state updates are skipped."""
    from repro.models.ssm import _chunk_conv, advance_conv_cache
    bsz, t, _ = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(linear(h, p["wg"], x.dtype))       # [B,T,R]
    u = linear(h, p["wx"], x.dtype)                       # [B,T,R]
    timeline = jnp.concatenate([cache["conv"], u], axis=1)
    v = _chunk_conv(timeline, p["conv"].astype(x.dtype),
                    p["conv_bias"].astype(x.dtype), t)
    a, b = _gates(p, v)                                   # [B,T,R] f32
    a = jnp.where(valid[..., None], a, 1.0)               # pad: a=1, b=0
    b = jnp.where(valid[..., None], b, 0.0)

    def step(state, inp):
        a_t, b_t = inp
        state = a_t * state + b_t
        return state, state

    state, hseq = jax.lax.scan(step, cache["state"],
                               (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    hseq = hseq.transpose(1, 0, 2)                        # [B,T,R]
    y = linear(hseq.astype(x.dtype) * gate, p["wo"], x.dtype)
    lens = jnp.sum(valid.astype(jnp.int32), axis=1)
    return y, dict(conv=advance_conv_cache(timeline, lens, cfg.conv_width),
                   state=state)


def rglru_decode(p, x, cfg: ModelConfig, cache):
    """One-step decode — the T=1 specialization of ``rglru_chunk``.
    x [B,1,D]."""
    return rglru_chunk(p, x, cfg, cache,
                       jnp.ones((x.shape[0], 1), jnp.bool_))
