"""Shared neural net layers (functional, pytree params, no framework deps).

All linear weights are stored [in, out] (y = x @ W) so GLVQ's input-channel
grouping applies directly. Initializers return pytrees of f32 arrays; forward
functions accept a ``dtype`` for compute casting (bf16 on TPU).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qtensor
from repro.core.qtensor import QuantTensor
from repro.kernels import attention as attn_kernels
from repro.kernels import kv_cache

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


# ---------------------------------------------------------------------------
# quantized-execution dispatch
# ---------------------------------------------------------------------------

def linear(x, w, dtype=None):
    """y = x @ w for a dense [K, N] weight or a QuantTensor.

    The single matmul call site for the model stack: quantized weights
    dispatch through the backend engine (fused decode+GEMM on TPU — the
    dense weight never materializes); dense weights take the plain GEMM.
    """
    dt = dtype or x.dtype
    if isinstance(w, QuantTensor):
        return w.matmul(x, out_dtype=dt)
    return x @ w.astype(dt)


def linear_cols(x, ws, dtype=None):
    """(x @ w for w in ws) for weights sharing the same input activations.

    Quantized weights fuse into ONE engine dispatch (``qtensor.matmul_cols``):
    the q/k/v projections of a block stop streaming the activation slab three
    times.  Dense (or unfusable) weights fall back to per-weight ``linear``.
    """
    dt = dtype or x.dtype
    if all(isinstance(w, QuantTensor) for w in ws):
        return qtensor.matmul_cols(ws, x, out_dtype=dt)
    return tuple(linear(x, w, dt) for w in ws)


def expert_linear(xb, w, dtype=None):
    """Per-expert matmul: xb [g, e, cap, d] x w [e, d, f] -> [g, e, cap, f].

    QuantTensor experts run the zipped stacked path (one engine dispatch per
    expert slice); dense experts keep the einsum XLA already fuses well."""
    dt = dtype or xb.dtype
    if isinstance(w, QuantTensor):
        g, e, cap, d = xb.shape
        xt = xb.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
        y = w.matmul(xt, out_dtype=dt, zipped=True)
        return y.reshape(e, g, cap, -1).transpose(1, 0, 2, 3)
    return jnp.einsum("gecd,edf->gecf", xb, w.astype(dt))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (default + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_cos_sin(pos, hd: int, theta: float, dtype):
    """pos [...], returns cos/sin [..., hd//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, pos, theta: float):
    """x [B, S, H, hd], pos [B, S] -> rotated x."""
    hd = x.shape[-1]
    cos, sin = _rope_cos_sin(pos, hd, theta, x.dtype)   # [B, S, hd/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def apply_mrope(x, pos3, sections: Tuple[int, int, int], theta: float):
    """Qwen2-VL multimodal RoPE. pos3 [3, B, S]; sections sum to hd//2."""
    hd = x.shape[-1]
    cs = [_rope_cos_sin(pos3[i], hd, theta, x.dtype) for i in range(3)]
    # select section of the hd/2 frequency axis per position stream
    cos = jnp.concatenate([cs[i][0][..., sum(sections[:i]):sum(sections[:i + 1])]
                           for i in range(3)], axis=-1)
    sin = jnp.concatenate([cs[i][1][..., sum(sections[:i]):sum(sections[:i + 1])]
                           for i in range(3)], axis=-1)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = dict(
        ln=jnp.ones((d,), jnp.float32),
        wq=dense_init(ks[0], d, cfg.n_heads * hd),
        wk=dense_init(ks[1], d, cfg.n_kv_heads * hd),
        wv=dense_init(ks[2], d, cfg.n_kv_heads * hd),
        wo=dense_init(ks[3], cfg.n_heads * hd, d),
    )
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p, x, cfg: ModelConfig, pos, *, cross_kv=None):
    b, s, _ = x.shape
    hd = cfg.hd
    if cross_kv is None:
        q, k, v = linear_cols(x, (p["wq"], p["wk"], p["wv"]), x.dtype)
        sk = s
    else:
        q = linear(x, p["wq"])
        src = cross_kv
        sk = src.shape[1]
        k, v = linear_cols(src, (p["wk"], p["wv"]), x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, sk, cfg.n_kv_heads, hd)
    v = v.reshape(b, sk, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cross_kv is None and cfg.rope_kind == "default":
        q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
    elif cross_kv is None and cfg.rope_kind == "mrope":
        q = apply_mrope(q, pos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int):
    """q [B,S,H,hd]; k/v [B,Sk,KV,hd]; mask broadcastable to [B,H,S,Sk]."""
    b, s, h, hd = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    q = q.reshape(b, s, kv, n_rep, hd)
    scores = jnp.einsum("bsgrd,btgd->bgrst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v)
    return out.reshape(b, s, h, hd)


def causal_mask(s: int, dtype=jnp.bool_):
    return jnp.tril(jnp.ones((s, s), dtype))[None, None, None]  # [1,1,1,S,S]


def attention(p, x, cfg: ModelConfig, pos, *, causal: bool = True,
              cross_kv=None):
    """Full (global) attention; causal for decoders."""
    b, s, d = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, x, cfg, pos, cross_kv=cross_kv)
    mask = None
    if causal and cross_kv is None:
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))[None, None, None]
    out = _sdpa(q, k, v, mask, n_rep)
    return linear(out.reshape(b, s, -1), p["wo"], x.dtype)


def local_attention(p, x, cfg: ModelConfig, pos):
    """Sliding-window causal attention, blocked so cost is O(S * 2W).

    Queries in block i attend to keys in blocks i-1 and i within the window.
    """
    b, s, d = x.shape
    w = cfg.window
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, x, cfg, pos)
    pad = -s % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nb = sp // w
    qb = q.reshape(b, nb, w, cfg.n_heads, cfg.hd)
    kb = k.reshape(b, nb, w, cfg.n_kv_heads, cfg.hd)
    vb = v.reshape(b, nb, w, cfg.n_kv_heads, cfg.hd)
    # keys: previous block ++ own block  -> [b, nb, 2w, kv, hd]
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    # mask: query t (in-block), key u in [0, 2w): absolute distance
    t = jnp.arange(w)[:, None]
    u = jnp.arange(2 * w)[None, :]
    dist = (t + w) - u
    base = (dist >= 0) & (dist < w)              # causal: self + (w-1) back
    first_block = jnp.arange(nb)[:, None, None] > 0
    valid_prev = (u < w)[None]
    mask = base[None] & (first_block | ~valid_prev)  # block 0 has no prev keys
    mask = mask[None, :, None, None]                 # [1, nb, 1, 1, w, 2w]

    qb2 = qb.reshape(b, nb, w, cfg.n_kv_heads, n_rep, cfg.hd)
    scores = jnp.einsum("bnsgrd,bntgd->bngrst", qb2, k2).astype(jnp.float32)
    scores = scores * (cfg.hd ** -0.5)
    mask_b = jnp.broadcast_to(mask, (1, nb, 1, 1, w, 2 * w))
    scores = jnp.where(mask_b[:, :, :, :, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v2.dtype)
    out = jnp.einsum("bngrst,bntgd->bnsgrd", probs, v2)
    out = out.reshape(b, sp, cfg.n_heads * cfg.hd)[:, :s]
    return linear(out, p["wo"], x.dtype)


def _chunk_qkv(p, x, cfg: ModelConfig, pos):
    """q/k/v projection + qk-norm + RoPE for the serving step.
    x [B, T, D]; pos [B] first absolute position per slot (token t of slot b
    sits at pos[b] + t).  T=1 is single-token decode."""
    b, t, _ = x.shape
    pos2 = pos[:, None] + jnp.arange(t)[None]                 # [B, T]
    if cfg.rope_kind == "mrope":
        return _qkv(p, x, cfg, jnp.broadcast_to(pos2[None], (3, b, t)))
    return _qkv(p, x, cfg, pos2)


def _decode_attend(q, ck, cv, valid, cfg: ModelConfig):
    """Masked attention over gathered history.
    q [B,Sq,H,hd]; ck/cv [B,Sk,KV,hd]; valid [B,Sk] (shared by all queries)
    or [B,Sq,Sk] (per-query) bool -> out [B,Sq,H*hd]."""
    return attn_kernels.masked_sdpa(q, ck, cv, valid,
                                 n_rep=cfg.n_heads // cfg.n_kv_heads,
                                 scale=cfg.hd ** -0.5)


# the mask math lives with the attention kernels now (the fused Pallas path
# replicates it in-kernel); the dense-cache path here keeps using it
_ring_positions = attn_kernels.ring_positions
_window_chunk_masks = attn_kernels.window_chunk_masks


def attention_chunk(p, x, cfg: ModelConfig, cache, pos, lens, *,
                    window: int = 0):
    """Variable-width serving step against the dense cache.

    x [B, T, D] token slab; pos [B] first absolute position per slot; lens
    [B] number of valid slab tokens (0 = idle slot; tokens t >= lens[b] are
    pad whose K/V writes are dropped and whose outputs are garbage the
    caller masks).  T=1 with lens=1 is exactly single-token decode.  Window
    > 0 writes ring-style; T must not exceed the ring length (earlier chunk
    keys would be overwritten before this step's attention reads them)."""
    b, t, _ = x.shape
    q, k, v = _chunk_qkv(p, x, cfg, pos)
    s_cache = cache["k"].shape[1]
    if window and t > window:
        raise ValueError(
            f"chunk of {t} tokens exceeds the sliding-window ring length "
            f"{window}; clamp chunk_size to the smallest local window")
    tt = jnp.arange(t)[None]                                  # [1, T]
    apos = pos[:, None] + tt                                  # [B, T]
    valid_q = tt < lens[:, None]
    idx = (apos % window) if window else apos
    idx = jnp.where(valid_q, idx, s_cache)    # OOB -> dropped by the scatter
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
    ck = cache["k"].at[bi, idx].set(k, mode="drop")
    cv = cache["v"].at[bi, idx].set(v, mode="drop")
    aq = apos[:, :, None]                                     # [B, T, 1]
    if window:
        # the chunk's ring writes overwrite slots its own earlier queries
        # still need: attend over [pre-append ring ++ in-flight chunk keys]
        hist, intra = _window_chunk_masks(pos, apos, t, s_cache, window)
        kk = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], 1)
        vv = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], 1)
        valid = jnp.concatenate(
            [hist, jnp.broadcast_to(intra, (b, t, t))], axis=-1)
        out = _decode_attend(q, kk, vv, valid, cfg)
    else:
        valid = jnp.arange(s_cache)[None, None, :] <= aq
        out = _decode_attend(q, ck, cv, valid, cfg)
    return linear(out, p["wo"], x.dtype), dict(k=ck, v=cv)


def attention_decode(p, x, cfg: ModelConfig, cache, pos, *, window: int = 0):
    """One-token decode — the T=1 specialization of ``attention_chunk``.
    x [B, 1, D]; cache dict(k, v) [B, S_cache, KV, hd]; pos [B] (or scalar)
    current absolute position. Window > 0 => ring buffer cache."""
    b = x.shape[0]
    pos_v = pos if pos.ndim else jnp.broadcast_to(pos[None], (b,))
    return attention_chunk(p, x, cfg, cache, pos_v,
                           jnp.ones((b,), jnp.int32), window=window)


def attn_cache_init(cfg: ModelConfig, batch: int, s_cache: int, dtype):
    return dict(
        k=jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
        v=jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
    )


# ---------------------------------------------------------------------------
# paged attention cache (block pools + shared table; see serving.kvcache)
# ---------------------------------------------------------------------------

def static_local_table(batch: int, blocks_per_slot: int) -> jnp.ndarray:
    """Contiguous per-slot block ownership for a layer-private ring pool:
    slot b owns blocks [1 + b*bps, 1 + (b+1)*bps) of its own pool."""
    base = 1 + blocks_per_slot * jnp.arange(batch)[:, None]
    return (base + jnp.arange(blocks_per_slot)[None]).astype(jnp.int32)


def paged_attn_cache_init(cfg: ModelConfig, num_blocks: int, block_size: int,
                          dtype, kind: str, *, batch: int = 0,
                          s_cache: int = 0, local: bool = False,
                          glvq=None, book=None):
    """Per-layer block pools for the paged cache modes.

    Global attention layers share the scheduler-managed block geometry (the
    per-slot table in ``cache["table"]`` indexes their pools uniformly).
    Sliding-window layers (``local=True``) only ever touch a ring of
    ``min(window, s_cache)`` positions, so their pools shrink to
    ``ceil(ring / block_size)`` statically-owned blocks per slot (plus
    scratch block 0) with a baked-in table ``lt`` — HBM tracks the window,
    not the global worst-case depth."""
    if local and cfg.window and batch:
        ring = min(cfg.window, s_cache) if s_cache else cfg.window
        nb_l = -(-ring // block_size)
        pools = kv_cache.pool_init(1 + batch * nb_l, block_size,
                                   cfg.n_kv_heads, cfg.hd, dtype, kind,
                                   glvq=glvq, book=book)
        pools["lt"] = static_local_table(batch, nb_l)
        return pools
    return kv_cache.pool_init(num_blocks, block_size, cfg.n_kv_heads, cfg.hd,
                              dtype, kind, glvq=glvq, book=book)


def paged_attention_chunk(p, x, cfg: ModelConfig, cache, table, pos, lens, *,
                          window: int = 0, kind: str = "paged",
                          kv_backend=None, attn_backend=None, mesh=None,
                          glvq=None):
    """Variable-width serving step against the paged cache.

    cache holds this layer's pools (``kp``/``vp`` + scales); table [B, nb]
    maps the slot's logical blocks to pool blocks.  All of a slot's chunk
    writes land in one ``append_chunk`` kernel call — whole blocks per step
    instead of one token at a time.  Window > 0 writes ring-style at
    ``pos % window``, touching only the slot's first ceil(window/bs) table
    entries, exactly mirroring the dense ring buffer (T <= window).

    Attention itself dispatches through ``kernels.attention``
    (``attn_backend``: fused Pallas block-walk vs. the gather-then-SDPA
    oracle; ``mesh`` shard_maps it over TP head shards)."""
    b, t, _ = x.shape
    q, k, v = _chunk_qkv(p, x, cfg, pos)
    bs = cache["kp"].shape[1]
    if window and t > window:
        raise ValueError(
            f"chunk of {t} tokens exceeds the sliding-window ring length "
            f"{window}; clamp chunk_size to the smallest local window")
    tt = jnp.arange(t)[None]
    apos = pos[:, None] + tt                                  # [B, T]
    valid_q = tt < lens[:, None]
    p_eff = (apos % window) if window else apos
    nb_l = -(-window // bs) if window else table.shape[1]
    j = jnp.clip(p_eff // bs, 0, nb_l - 1)                    # [B, T]
    bids = jnp.take_along_axis(table, j, axis=1)
    # the (<= NBT) distinct pool blocks a slot's chunk touches: a cyclic walk
    # of consecutive logical blocks from the first token's block (positions
    # are consecutive, so touched blocks are too); out-of-range entries fall
    # back to scratch 0 so the Pallas grid never double-visits a live block
    nbt = min((t + bs - 2) // bs + 1, nb_l)
    pj_raw = j[:, :1] + jnp.arange(nbt)[None]                 # [B, NBT]
    pj = (pj_raw % nb_l) if window else jnp.minimum(pj_raw, nb_l - 1)
    prog_bids = jnp.take_along_axis(table, pj, axis=1)
    if not window:
        prog_bids = jnp.where(pj_raw < nb_l, prog_bids, 0)
    if window:
        # attend BEFORE this chunk's writes land (they overwrite ring slots
        # earlier queries still need): [pre-append ring ++ in-flight chunk
        # keys], the chunk keys roundtripped through the cache codec so
        # intra-chunk reads match what a later gather would return
        k_rt, v_rt = kv_cache.chunk_roundtrip(
            k, v, mode=kind, store_dtype=cache["kp"].dtype, out_dtype=x.dtype,
            glvq=glvq, book=cache if kind == "paged_glvq" else None)
        out = attn_kernels.paged_attention(
            q, cache, table[:, :nb_l], pos, lens, mode=kind, window=window,
            k_chunk=k_rt, v_chunk=v_rt, kv_backend=kv_backend,
            backend=attn_backend, mesh=mesh, out_dtype=x.dtype, glvq=glvq)
        cache = kv_cache.append_chunk(cache, k, v, bids,
                                      (p_eff % bs).astype(jnp.int32),
                                      valid_q, prog_bids,
                                      mode=kind, backend=kv_backend,
                                      glvq=glvq)
    else:
        cache = kv_cache.append_chunk(cache, k, v, bids,
                                      (p_eff % bs).astype(jnp.int32),
                                      valid_q, prog_bids,
                                      mode=kind, backend=kv_backend,
                                      glvq=glvq)
        out = attn_kernels.paged_attention(
            q, cache, table[:, :nb_l], pos, lens, mode=kind, window=0,
            kv_backend=kv_backend, backend=attn_backend, mesh=mesh,
            out_dtype=x.dtype, glvq=glvq)
    return linear(out, p["wo"], x.dtype), cache


def paged_attention_decode(p, x, cfg: ModelConfig, cache, table, pos, *,
                           window: int = 0, kind: str = "paged",
                           kv_backend=None, attn_backend=None, mesh=None,
                           glvq=None):
    """One-token decode — the T=1 specialization of
    ``paged_attention_chunk``."""
    b = x.shape[0]
    pos_v = pos if pos.ndim else jnp.broadcast_to(pos[None], (b,))
    return paged_attention_chunk(p, x, cfg, cache, table, pos_v,
                                 jnp.ones((b,), jnp.int32), window=window,
                                 kind=kind, kv_backend=kv_backend,
                                 attn_backend=attn_backend, mesh=mesh,
                                 glvq=glvq)


# ---------------------------------------------------------------------------
# MLP (dense + MoE)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = dict(ln=jnp.ones((d,), jnp.float32),
             w1=dense_init(ks[0], d, f),
             w2=dense_init(ks[1], f, d))
    if cfg.act == "swiglu":
        p["w3"] = dense_init(ks[2], d, f)
    return p


def mlp(p, x, cfg: ModelConfig):
    h = linear(x, p["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * linear(x, p["w3"])
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return linear(h, p["w2"], x.dtype)


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    init = lambda k, i, o: jax.random.normal(k, (e, i, o), jnp.float32) * (i ** -0.5)
    p = dict(ln=jnp.ones((d,), jnp.float32),
             router=dense_init(ks[0], d, e),
             w1=init(ks[1], d, f),
             w2=init(ks[2], f, d))
    if cfg.act == "swiglu":
        p["w3"] = init(ks[3], d, f)
    return p


def _constrain(x, *specs):
    """Apply the first sharding constraint whose axes exist; no-op without a
    mesh context (unit tests, single device)."""
    from jax.sharding import PartitionSpec as P
    for spec in specs:
        try:
            return jax.lax.with_sharding_constraint(x, P(*spec))
        except Exception:
            continue
    return x


_DP = (("pod", "data"),)   # batch-like dims: shard over all DP axes
_DP1 = ("data",)


def moe(p, x, cfg: ModelConfig, *, chunks: int = 0):
    """Top-k MoE: CHUNKED sort-based capacity dispatch with explicit
    shardings (chunks over the data axes, experts over the model axis).

    Routing (top-k, sort, bucket indices) is chunk-local, so the only
    cross-device traffic is the expert-parallel all-to-all moving bucketed
    activations between the data and expert shardings — the sharding
    constraints below pin that plan down for GSPMD (without them it
    all-gathers the bucket arrays over the data axis: 60x more bytes).
    Capacity is enforced per chunk (standard practice).
    """
    b, s, d = x.shape
    t_all = b * s
    e, k = cfg.n_experts, cfg.top_k
    if chunks <= 0:
        chunks = min(32, t_all) if t_all >= 64 else 1
    while t_all % chunks:
        chunks -= 1
    g = chunks
    tc = t_all // g
    cap = max(4, min(int(cfg.capacity_factor * tc * k / e), tc))

    xc = _constrain(x.reshape(g, tc, d), (_DP[0], None, None),
                    (_DP1[0], None, None), ())
    gates = jax.nn.softmax(linear(xc, p["router"], x.dtype).astype(jnp.float32))
    topv, topi = jax.lax.top_k(gates, k)                     # [g, tc, k]
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)

    n = tc * k
    flat_e = topi.reshape(g, n)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(tc), k)[None], (g, n))
    flat_w = topv.reshape(g, n)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    # GATHER-ONLY dispatch: expert e's bucket slots are the contiguous run
    # [starts[e], starts[e]+cap) of the sorted order — no scatter anywhere
    # (GSPMD partitions batched gathers along g cleanly; scatters it doesn't).
    eids = jnp.arange(e)
    starts = jax.vmap(lambda a: jnp.searchsorted(a, eids, side="left"))(se)
    ends = jax.vmap(lambda a: jnp.searchsorted(a, eids, side="right"))(se)
    src = starts[:, :, None] + jnp.arange(cap)[None, None, :]   # [g, e, cap]
    valid = src < ends[:, :, None]
    src_c = jnp.minimum(src, n - 1).reshape(g, e * cap)
    tok = jnp.take_along_axis(st, src_c, axis=-1)               # [g, e*cap]
    xb = jnp.take_along_axis(xc, tok[..., None], axis=1)
    xb = xb.reshape(g, e, cap, d) * valid[..., None].astype(x.dtype)
    # expert-parallel segment: chunks stay on data axes, experts on model
    xb = _constrain(xb, (_DP[0], "model", None, None),
                    (_DP1[0], "model", None, None), ())
    h = expert_linear(xb, p["w1"], x.dtype)
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * expert_linear(xb, p["w3"], x.dtype)
    else:
        h = jax.nn.gelu(h)
    yb = expert_linear(h, p["w2"], x.dtype)
    yb = yb * valid[..., None].astype(x.dtype)
    # keep ybuf EXPERT-SHARDED: the combine gather then lowers to a masked
    # partial gather + all-reduce of [g, tc*k, d] (tokens) instead of an
    # all-gather of the full [g, e*cap, d] bucket array (1.25x larger and
    # replicated to every model shard).
    ybuf = yb.reshape(g, e * cap, d)
    ybuf = _constrain(ybuf, (_DP[0], "model", None),
                      (_DP1[0], "model", None), ())
    # combine: unsort (argsort of a permutation = its inverse), then gather
    # each token's k bucket slots — again no scatter.
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(se)
    pos_in_e = jnp.arange(n)[None] - first
    keep = pos_in_e < cap
    slot = jnp.minimum(se * cap + pos_in_e, e * cap - 1)        # [g, n] sorted
    inv = jnp.argsort(order, axis=-1)
    slot_tj = jnp.take_along_axis(slot, inv, axis=-1)           # [g, n] token order
    keep_tj = jnp.take_along_axis(keep, inv, axis=-1)
    contrib = jnp.take_along_axis(ybuf, slot_tj[..., None], axis=1)  # [g, n, d]
    contrib = contrib * (flat_w * keep_tj).astype(x.dtype)[..., None]
    out = contrib.reshape(g, tc, k, d).sum(axis=2)
    return out.reshape(b, s, d)
