"""Unified decoder-only LM covering dense / moe / vlm / hybrid / ssm families.

The layer stack is (scan_unit x n_repeats) + scan_tail; homogeneous params are
stacked on a leading repeat axis and executed with jax.lax.scan (keeps the HLO
small — essential for 512-way SPMD compiles) with optional remat.

Block kinds: "attn" (global attention + MLP), "attn_local" (sliding-window
attention + MLP), "attn_moe" (attention + MoE), "rglru" (RG-LRU + MLP),
"mamba" (Mamba-2 SSD, no separate MLP).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qtensor
from repro.kernels import kv_cache
from repro.models import layers, rglru, ssm
from repro.models.layers import rms_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# block init / apply / decode
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "attn_local"):
        return dict(attn=layers.attn_init(k1, cfg), mlp=layers.mlp_init(k2, cfg))
    if kind == "attn_moe":
        return dict(attn=layers.attn_init(k1, cfg), moe=layers.moe_init(k2, cfg))
    if kind == "rglru":
        return dict(rec=rglru.rglru_init(k1, cfg), mlp=layers.mlp_init(k2, cfg))
    if kind == "mamba":
        return dict(m=ssm.mamba_init(k1, cfg))
    raise ValueError(kind)


def block_apply(p: Params, x, cfg: ModelConfig, kind: str, pos):
    if kind in ("attn", "attn_local", "attn_moe"):
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        if kind == "attn_local" and cfg.window:
            x = x + layers.local_attention(p["attn"], h, cfg, pos)
        else:
            x = x + layers.attention(p["attn"], h, cfg, pos, causal=True)
        if kind == "attn_moe":
            h = rms_norm(x, p["moe"]["ln"], cfg.norm_eps)
            return x + layers.moe(p["moe"], h, cfg)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg)
    if kind == "rglru":
        x = x + rglru.rglru_forward(p["rec"], x, cfg)
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg)
    if kind == "mamba":
        return x + ssm.mamba_forward(p["m"], x, cfg)
    raise ValueError(kind)


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, s_cache: int,
                     dtype, *, cache_kind: str = "dense", block_size: int = 16,
                     num_blocks: int = 0, glvq=None):
    if kind in ("attn", "attn_local", "attn_moe"):
        if cache_kind != "dense":
            # sliding-window layers get a layer-private ring pool sized to
            # ceil(min(window, s_cache)/block_size) blocks per slot (plus a
            # baked-in table "lt") instead of the global pool depth
            return layers.paged_attn_cache_init(
                cfg, num_blocks, block_size, dtype, cache_kind, batch=batch,
                s_cache=s_cache, local=(kind == "attn_local"), glvq=glvq)
        if kind == "attn_local":
            return layers.attn_cache_init(cfg, batch,
                                          min(cfg.window, s_cache), dtype)
        return layers.attn_cache_init(cfg, batch, s_cache, dtype)
    if kind == "rglru":
        return rglru.rglru_cache_init(cfg, batch, dtype)
    if kind == "mamba":
        return ssm.mamba_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


def block_chunk(p: Params, x, cfg: ModelConfig, kind: str, cache, pos, lens,
                valid, *, pages=None):
    """Variable-width serving step for one block.  x [B, T, D]; pos [B] first
    absolute position; lens [B] valid slab tokens per slot; valid [B, T] the
    matching mask.  ``pages`` is None for the dense cache, else a dict with
    the shared block ``table`` [B, blocks_per_slot] plus static ``kind`` /
    ``backend`` / ``attn_backend`` / ``mesh`` routing the attention layers
    through the paged KV + attention kernel registries (sliding-window
    layers use their layer-private ``cache["lt"]`` ring table instead of
    the shared one)."""
    if kind in ("attn", "attn_local", "attn_moe"):
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        if pages is not None:
            # ring length must match the dense oracle's min(window, s_cache);
            # the block-rounded capacity only bounds it when s_cache is unknown
            cap = pages["s_cache"] or \
                pages["table"].shape[1] * cache["kp"].shape[1]
            win = min(cfg.window, cap) if kind == "attn_local" else 0
            table = cache["lt"] if kind == "attn_local" and "lt" in cache \
                else pages["table"]
            out, cache = layers.paged_attention_chunk(
                p["attn"], h, cfg, cache, table, pos, lens, window=win,
                kind=pages["kind"], kv_backend=pages["backend"],
                attn_backend=pages.get("attn_backend"),
                mesh=pages.get("mesh"), glvq=pages.get("glvq"))
        else:
            win = min(cfg.window, cache["k"].shape[1]) \
                if kind == "attn_local" else 0
            out, cache = layers.attention_chunk(p["attn"], h, cfg, cache,
                                                pos, lens, window=win)
        x = x + out
        if kind == "attn_moe":
            h = rms_norm(x, p["moe"]["ln"], cfg.norm_eps)
            return x + layers.moe(p["moe"], h, cfg), cache
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg), cache
    if kind == "rglru":
        out, cache = rglru.rglru_chunk(p["rec"], x, cfg, cache, valid)
        x = x + out
        h = rms_norm(x, p["mlp"]["ln"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, cfg), cache
    if kind == "mamba":
        out, cache = ssm.mamba_chunk(p["m"], x, cfg, cache, valid)
        return x + out, cache
    raise ValueError(kind)


def block_decode(p: Params, x, cfg: ModelConfig, kind: str, cache, pos, *,
                 pages=None):
    """One-token decode — the T=1 specialization of ``block_chunk``."""
    b = x.shape[0]
    pos_v = pos if pos.ndim else jnp.broadcast_to(pos[None], (b,))
    return block_chunk(p, x, cfg, kind, cache, pos_v,
                       jnp.ones((b,), jnp.int32),
                       jnp.ones((b, 1), jnp.bool_), pages=pages)


# ---------------------------------------------------------------------------
# model init / forward
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 4)
    r = cfg.n_repeats
    blocks = []
    for i, kind in enumerate(cfg.scan_unit):
        ks = jax.random.split(jax.random.fold_in(keys[0], i), r)
        blocks.append(jax.vmap(lambda k: block_init(k, cfg, kind))(ks))
    tail = [block_init(jax.random.fold_in(keys[1], i), cfg, kind)
            for i, kind in enumerate(cfg.scan_tail)]
    p = dict(
        embed=jax.random.normal(keys[2], (cfg.vocab, cfg.d_model), jnp.float32)
        * cfg.d_model ** -0.5,
        final_ln=jnp.ones((cfg.d_model,), jnp.float32),
        blocks=tuple(blocks),
        tail=tail,
    )
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(keys[3], cfg.d_model, cfg.vocab)
    return p


def _quantized_view(params: Params, qmeta, backend, mesh=None) -> Params:
    """Wrap packed payload dicts into QuantTensor nodes (the engine entry).

    The scan over ``blocks`` then slices each QuantTensor's payload arrays to
    the current repeat — the paper's streaming decode (Sec 3.4) — and every
    matmul inside the blocks dispatches through the backend registry instead
    of materializing the dense weight in HBM.  With ``mesh``, each matmul
    runs tensor-parallel via shard_map on its local payload slice."""
    return qtensor.wrap_tree(params, qmeta, backend=backend, mesh=mesh)


def _backbone(params: Params, x, cfg: ModelConfig, pos, *, remat: bool = False,
              unroll: int = 1):
    def unit_apply(x, unit_params):
        for kind, p in zip(cfg.scan_unit, unit_params):
            x = block_apply(p, x, cfg, kind, pos)
        return x

    fn = jax.checkpoint(unit_apply) if remat else unit_apply

    def body(x, unit_params):
        return fn(x, unit_params), None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
    for kind, p in zip(cfg.scan_tail, params["tail"]):
        x = block_apply(p, x, cfg, kind, pos)
    return x


def embed_inputs(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                 dtype):
    """tokens (+ vlm vision stub) -> x [B, S, D], pos."""
    tokens = batch["tokens"]
    x = params["embed"].astype(dtype)[tokens]
    if cfg.family == "vlm" and "vision" in batch:
        x = jnp.concatenate([batch["vision"].astype(dtype), x], axis=1)
    s = x.shape[1]
    if cfg.rope_kind == "mrope":
        pos = batch.get("pos3")
        if pos is None:
            p1 = jnp.broadcast_to(jnp.arange(s)[None], (x.shape[0], s))
            pos = jnp.stack([p1, p1, p1])
    else:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (x.shape[0], s))
    return x, pos


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, dtype=jnp.bfloat16, remat: bool = False, qmeta=None,
            unroll: int = 1, backend=None, mesh=None):
    """logits [B, S, V] (f32)."""
    if qmeta:
        params = _quantized_view(params, qmeta, backend, mesh)
    x, pos = embed_inputs(params, batch, cfg, dtype)
    x = _backbone(params, x, cfg, pos, remat=remat, unroll=unroll)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ head.astype(dtype)).astype(jnp.float32)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, dtype=jnp.bfloat16, remat: bool = True, unroll: int = 1):
    logits = forward(params, batch, cfg, dtype=dtype, remat=remat, unroll=unroll)
    labels = batch["labels"]
    if cfg.family == "vlm" and "vision" in batch:
        # vision positions carry no LM loss
        nvis = batch["vision"].shape[1]
        logits = logits[:, nvis:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_init(cfg: ModelConfig, batch: int, s_cache: int, dtype, *,
               cache_kind: str = "dense", block_size: int = 16,
               num_blocks: Optional[int] = None, kv_bits: int = 4,
               kv_d: int = 0, kv_codebook=None) -> Params:
    """Decode cache for the whole stack.

    ``cache_kind="dense"`` (default): per-slot max-length K/V buffers — the
    parity oracle.  Paged kinds (``paged`` / ``paged_q8`` / ``paged_q8c`` /
    ``paged_glvq``) replace every attention layer's buffers with shared
    block pools plus one top-level block table ``cache["table"]``
    [batch, ceil(s_cache/block_size)] (block 0 is reserved scratch; see
    ``serving.kvcache``).  Recurrent layers (rglru / mamba) keep per-slot
    state either way.

    ``paged_glvq`` pools carry per-head codebook leaves: identity (uniform
    ``kv_bits``-bit) by default, overridden per layer by a calibrated
    ``kv_codebook`` (``data.calibration.KVCodebook`` — per-repeat arrays
    grafted after the scan-stack broadcast).  ``kv_d`` = 0 picks the
    largest supported lattice dim dividing ``cfg.hd``."""
    layout = None
    if cache_kind != "dense":
        layout = kv_cache.PageLayout.plan(s_cache, batch, block_size,
                                          num_blocks)
        num_blocks = layout.num_blocks
    glvq = None
    if cache_kind == "paged_glvq":
        glvq = kv_cache.default_glvq_spec(cfg.hd, bits=kv_bits,
                                          d=kv_d or None)
    kw = dict(cache_kind=cache_kind, block_size=block_size,
              num_blocks=num_blocks or 0, glvq=glvq)
    blocks = []
    for kind in cfg.scan_unit:
        one = block_cache_init(cfg, kind, batch, s_cache, dtype, **kw)
        blocks.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_repeats,) + a.shape), one))
    tail = [block_cache_init(cfg, kind, batch, s_cache, dtype, **kw)
            for kind in cfg.scan_tail]
    if kv_codebook is not None and cache_kind == "paged_glvq":
        for i, bk in enumerate(getattr(kv_codebook, "blocks", ()) or ()):
            if bk is not None and i < len(blocks):
                blocks[i] = dict(blocks[i], **{
                    n: jnp.asarray(bk[n], jnp.float32)
                    for n in kv_cache.GLVQ_BOOK_LEAVES})
        for i, bk in enumerate(getattr(kv_codebook, "tail", ()) or ()):
            if bk is not None and i < len(tail):
                tail[i] = dict(tail[i], **{
                    n: jnp.asarray(bk[n], jnp.float32)
                    for n in kv_cache.GLVQ_BOOK_LEAVES})
    cache = dict(blocks=tuple(blocks), tail=tail)
    if layout is not None:
        cache["table"] = jnp.zeros((batch, layout.blocks_per_slot), jnp.int32)
    return cache


_RECURRENT_KINDS = ("rglru", "mamba")


def has_recurrent(cfg: ModelConfig) -> bool:
    return any(k in _RECURRENT_KINDS
               for k in tuple(cfg.scan_unit) + tuple(cfg.scan_tail))


def reset_slot(cache: Params, cfg: ModelConfig, slot) -> Params:
    """Zero one batch slot's recurrent state (conv window + hidden state).

    Attention caches need no reset — their validity masks hide everything
    past a re-claimed slot's position — but recurrent layers integrate every
    step, so a retired request's state would leak into the next occupant."""
    def zero(tree, stacked: bool):
        if stacked:   # leading repeat axis from the scan stack: [R, B, ...]
            return jax.tree.map(lambda a: a.at[:, slot].set(0), tree)
        return jax.tree.map(lambda a: a.at[slot].set(0), tree)

    new_blocks = tuple(
        zero(c, True) if kind in _RECURRENT_KINDS else c
        for kind, c in zip(cfg.scan_unit, cache["blocks"]))
    new_tail = [zero(c, False) if kind in _RECURRENT_KINDS else c
                for kind, c in zip(cfg.scan_tail, cache["tail"])]
    return dict(cache, blocks=new_blocks, tail=new_tail)


def chunk_step(params: Params, cache: Params, tokens, pos, lens,
               cfg: ModelConfig, *, engine=None, dtype=jnp.bfloat16,
               qmeta=None, unroll: int = 1, backend=None,
               cache_kind: str = "dense", kv_backend=None,
               attn_backend=None, s_cache: Optional[int] = None, mesh=None,
               kv_bits: int = 4, kv_d: int = 0):
    """One variable-width serving step: the unified prefill/decode program.

    ``engine`` (a ``serving.engine.EngineConfig``, duck-typed here to keep
    the model layer import-free of serving) supersedes the loose execution
    kwargs when given.

    tokens [B, T] int32 token slab; pos [B] int32 first absolute position
    per slot; lens [B] int32 valid slab tokens per slot (0 = idle slot; a
    prefill slot consumes up to T prompt tokens, a decode slot exactly 1 —
    T=1 IS single-token decode, same code path).  Returns (logits [B, V]
    taken at each slot's LAST valid token, new cache).

    The backbone runs ONCE over the whole chunk, so every quantized matmul
    executes at M = B*T — the fused ``glvq_matmul`` M-blocking finally pays
    off during prefill — and paged attention layers write whole KV blocks
    per call via ``kv_cache.append_chunk``.  Pad positions (t >= lens[b])
    are masked everywhere that matters: their KV writes are dropped, their
    recurrent state updates are skipped, and their logits never selected."""
    if engine is not None:
        dtype, qmeta, unroll = engine.dtype, engine.qmeta, engine.unroll
        backend, cache_kind = engine.backend, engine.cache_kind
        kv_backend, s_cache, mesh = (engine.kv_backend, engine.s_cache,
                                     engine.mesh)
        attn_backend = engine.attn_backend
        kv_bits = getattr(engine, "kv_bits", kv_bits)
        kv_d = getattr(engine, "kv_d", kv_d)
    if qmeta:
        params = _quantized_view(params, qmeta, backend, mesh)
    pages = None
    if cache_kind != "dense":
        pages = dict(table=cache["table"], kind=cache_kind,
                     backend=kv_backend, attn_backend=attn_backend,
                     mesh=mesh, s_cache=s_cache)
        if cache_kind == "paged_glvq":
            pages["glvq"] = kv_cache.default_glvq_spec(cfg.hd, bits=kv_bits,
                                                       d=kv_d or None)
    b, t = tokens.shape
    valid = jnp.arange(t)[None] < lens[:, None]
    x = params["embed"].astype(dtype)[tokens]               # [B,T,D]

    def body(x, inp):
        unit_params, unit_cache = inp
        new_caches = []
        for kind, p, c in zip(cfg.scan_unit, unit_params, unit_cache):
            x, nc = block_chunk(p, x, cfg, kind, c, pos, lens, valid,
                                pages=pages)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]),
                                 unroll=unroll)
    new_tail = []
    for kind, p, c in zip(cfg.scan_tail, params["tail"], cache["tail"]):
        x, nc = block_chunk(p, x, cfg, kind, c, pos, lens, valid,
                            pages=pages)
        new_tail.append(nc)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    last = jnp.maximum(lens - 1, 0)                         # [B]
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]  # [B,D]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (xl @ head.astype(dtype)).astype(jnp.float32)
    new_cache = dict(blocks=new_blocks, tail=new_tail)
    if pages is not None:
        new_cache["table"] = cache["table"]
    return logits, new_cache


def decode_step(params: Params, cache: Params, token, pos, cfg: ModelConfig,
                *, engine=None, dtype=jnp.bfloat16, qmeta=None,
                unroll: int = 1, backend=None, cache_kind: str = "dense",
                kv_backend=None, attn_backend=None,
                s_cache: Optional[int] = None, mesh=None, kv_bits: int = 4,
                kv_d: int = 0):
    """One-token decode — the T=1 specialization of ``chunk_step``.
    token [B] int32, pos [B] (or scalar) int32 -> (logits [B, V], cache).

    ``engine`` (an ``EngineConfig``) supersedes the loose kwargs.  With
    ``qmeta``, every matmul against a quantized weight dispatches through
    ``QuantTensor.matmul`` — decoding reduces to a matrix-vector product and
    the dense weight never materializes on the fused backend.  With a paged
    ``cache_kind``, attention history reads/writes dispatch through the
    ``kernels.kv_cache`` backend registry instead of dense buffers.  With
    ``mesh``, quantized matmuls run tensor-parallel (shard_map) per shard."""
    b = token.shape[0]
    pos_v = pos if pos.ndim else jnp.broadcast_to(pos[None], (b,))
    if engine is not None:
        return chunk_step(params, cache, token[:, None], pos_v,
                          jnp.ones((b,), jnp.int32), cfg, engine=engine)
    return chunk_step(params, cache, token[:, None], pos_v,
                      jnp.ones((b,), jnp.int32), cfg, dtype=dtype,
                      qmeta=qmeta, unroll=unroll, backend=backend,
                      cache_kind=cache_kind, kv_backend=kv_backend,
                      attn_backend=attn_backend, s_cache=s_cache, mesh=mesh,
                      kv_bits=kv_bits, kv_d=kv_d)
