"""Model registry: uniform init / loss / decode API over all families,
plus ShapeDtypeStruct ``input_specs`` used by the multi-pod dry-run.

Serving entry points (``chunk_step`` / ``decode_step`` / ``cache_init``)
consume ONE ``serving.engine.EngineConfig`` (``engine=...``): dtype, GLVQ
``qmeta`` + matmul ``backend`` (QuantTensor dispatch), ``cache_kind`` /
``block_size`` / ``kv_backend`` / ``s_cache`` (pluggable paged attention
cache), and ``mesh`` (tensor-parallel shard_map).  The PR-4 loose-kwarg
spellings (``dtype=..., qmeta=..., cache_kind=..., ...``) keep working
through ``_as_engine``, the one back-compat shim that folds them into an
EngineConfig.  The encoder-decoder family keeps a dense cache (its decoder
contexts are short); its cache knobs are validated/stripped here rather
than at every call site."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm, whisper

Params = Dict[str, Any]


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.enc_layers > 0


def init_params(key, cfg: ModelConfig) -> Params:
    if is_encdec(cfg):
        return whisper.init_params(key, cfg)
    return lm.init_params(key, cfg)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def _as_engine(engine, kw: Dict[str, Any]):
    """The loose-kwarg back-compat shim: fold legacy serving kwargs into an
    ``EngineConfig``.  Every call site in the repo passes ``engine=`` now;
    this keeps external ``dtype=... qmeta=... cache_kind=...`` spellings
    working (and rejects mixing the two)."""
    # local import: repro.serving.scheduler imports this module
    from repro.serving.engine import EngineConfig
    if engine is not None:
        if kw:
            raise TypeError("pass either engine=EngineConfig(...) or the "
                            f"legacy loose kwargs, not both: got {sorted(kw)}")
        return engine
    return EngineConfig(**kw)


def _check_encdec_cache(cfg: ModelConfig, engine) -> None:
    if engine.cache_kind != "dense":
        raise ValueError(f"{cfg.arch}: the encoder-decoder family only "
                         "supports the dense cache")


def loss_fn(params, batch, cfg: ModelConfig, **kw):
    if is_encdec(cfg):
        return whisper.loss_fn(params, batch, cfg, **kw)
    return lm.loss_fn(params, batch, cfg, **kw)


def forward(params, batch, cfg: ModelConfig, **kw):
    if is_encdec(cfg):
        return whisper.forward(params, batch, cfg, **kw)
    return lm.forward(params, batch, cfg, **kw)


def decode_step(params, cache, token, pos, cfg: ModelConfig, *,
                engine=None, **kw):
    engine = _as_engine(engine, kw)
    if is_encdec(cfg):
        _check_encdec_cache(cfg, engine)
        return whisper.decode_step(params, cache, token, pos, cfg,
                                   dtype=engine.dtype, unroll=engine.unroll,
                                   qmeta=engine.qmeta, backend=engine.backend,
                                   mesh=engine.mesh)
    return lm.decode_step(params, cache, token, pos, cfg, engine=engine)


def chunk_step(params, cache, tokens, pos, lens, cfg: ModelConfig, *,
               engine=None, **kw):
    """One variable-width serving step (unified prefill/decode): tokens
    [B, T] slab + per-slot first positions / valid lengths -> (logits [B, V]
    at each slot's last valid token, cache).  T=1 is single-token decode —
    the same compiled program family as ``decode_step``."""
    engine = _as_engine(engine, kw)
    if is_encdec(cfg):
        raise ValueError(f"{cfg.arch}: the encoder-decoder family has no "
                         "chunked serving step (its decoder contexts are "
                         "short; drive it token-by-token via decode_step)")
    from repro.serving import trace      # lazy: tracing-time only, no cycle
    with trace.annotate("chunk_step"):
        return lm.chunk_step(params, cache, tokens, pos, lens, cfg,
                             engine=engine)


def cache_init(cfg: ModelConfig, batch: int, s_cache: Optional[int] = None,
               dtype=None, *, engine=None, **kw):
    """Serving cache for ``batch`` slots.  Either pass ``engine=`` (an
    ``EngineConfig``; its s_cache/dtype/cache_kind/block_size/num_blocks
    drive the geometry) or the legacy positional ``s_cache``/``dtype`` plus
    loose cache kwargs."""
    if engine is not None:
        if s_cache is not None or dtype is not None or kw:
            raise TypeError("cache_init(engine=...) takes its geometry from "
                            "the EngineConfig; don't also pass "
                            "s_cache/dtype/cache kwargs")
    else:
        if s_cache is None:
            raise TypeError("cache_init requires s_cache (positionally or "
                            "via engine=EngineConfig(...))")
        engine = _as_engine(None, dict(
            kw, s_cache=s_cache,
            dtype=jnp.bfloat16 if dtype is None else dtype))
    if engine.s_cache is None:
        raise ValueError("cache_init needs a concrete EngineConfig.s_cache "
                         "to size the cache")
    s_cache, dtype = engine.s_cache, engine.dtype
    cache_kind, block_size = engine.cache_kind, engine.block_size
    num_blocks = engine.num_blocks
    if is_encdec(cfg):
        if cache_kind != "dense":
            raise ValueError(f"{cfg.arch}: the encoder-decoder family only "
                             "supports the dense cache")
        return whisper.cache_init(cfg, batch, s_cache,
                                  max(s_cache // cfg.frontend_stride, 8), dtype)
    return lm.cache_init(cfg, batch, s_cache, dtype, cache_kind=cache_kind,
                         block_size=block_size, num_blocks=num_blocks,
                         kv_bits=getattr(engine, "kv_bits", 4),
                         kv_d=getattr(engine, "kv_d", 0),
                         kv_codebook=getattr(engine, "kv_codebook", None))


def has_recurrent(cfg: ModelConfig) -> bool:
    """True when slot reuse needs per-slot state resets (ssm / hybrid)."""
    return not is_encdec(cfg) and lm.has_recurrent(cfg)


def reset_slot(cache, cfg: ModelConfig, slot):
    """Zero one batch slot's recurrent state; no-op for attention-only
    families (their validity masks make stale cache content unreachable)."""
    if is_encdec(cfg) or not lm.has_recurrent(cfg):
        return cache
    return lm.reset_slot(cache, cfg, slot)


def cache_specs(cfg: ModelConfig, batch: int, s_cache: int, dtype=jnp.bfloat16,
                **kw):
    return jax.eval_shape(
        functools.partial(cache_init, cfg, batch, s_cache, dtype, **kw))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for the given (arch x shape) cell, per the shape's kind."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    i32 = jnp.int32
    if kind in ("train", "prefill"):
        if is_encdec(cfg):
            s_text = max(s // cfg.frontend_stride, 8)
            spec = dict(frames=_sds((b, s, cfg.d_model), dtype),
                        tokens=_sds((b, s_text), i32))
            if kind == "train":
                spec["labels"] = _sds((b, s_text), i32)
            return spec
        if cfg.family == "vlm":
            nv = cfg.n_vision_tokens
            spec = dict(tokens=_sds((b, s - nv), i32),
                        vision=_sds((b, nv, cfg.d_model), dtype),
                        pos3=_sds((3, b, s), i32))
            if kind == "train":
                spec["labels"] = _sds((b, s - nv), i32)
            return spec
        spec = dict(tokens=_sds((b, s), i32))
        if kind == "train":
            spec["labels"] = _sds((b, s), i32)
        return spec
    if kind == "decode":
        # uniform decode position (scalar) => one in-place cache update;
        # per-request positions remain supported by the model code itself.
        return dict(token=_sds((b,), i32), pos=_sds((), i32))
    raise ValueError(kind)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only runs for sub-quadratic decode (SSM / hybrid)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("skip: pure full-attention decode at 524k context has "
                       "no sub-quadratic mechanism (see DESIGN.md)")
    return True, ""
